//! Benchmark harness (substrate S10) — regenerates every table and figure
//! of the paper's evaluation (Section 5). Used by `rust/benches/*` (via
//! `cargo bench`, `harness = false`) and the `aipso bench` CLI.
//!
//! The metric is the paper's: **sorting rate in keys/second**, mean of
//! `reps` runs on freshly cloned inputs (the paper uses 10 runs of
//! N = 10⁸; defaults here are CI-sized and overridable with
//! `AIPSO_N` / `AIPSO_REPS` / `--n` / `--reps`).

pub mod balance;

use crate::datasets::{self, FigureGroup, KeyType};
use crate::key::SortKey;
use crate::rmi::model::{Rmi, RmiConfig};
use crate::rmi::quality;
use crate::util::rng::Xoshiro256pp;
use crate::util::{fmt, stats};
use crate::{sort_parallel, sort_sequential, SortEngine};

/// Sizing and repetition knobs shared by every figure/bench runner.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Synthetic dataset size (real-world sets scale by their paper
    /// factor).
    pub n: usize,
    /// Repetitions per (dataset, engine) cell; the paper uses 10.
    pub reps: usize,
    /// Worker threads for the parallel figures (0 = all cores).
    pub threads: usize,
    /// Base PRNG seed for dataset generation.
    pub seed: u64,
    /// Honour the paper's 2x size factor for real-world datasets.
    pub scale_real_world: bool,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            n: env_usize("AIPSO_N", 2_000_000),
            reps: env_usize("AIPSO_REPS", 3),
            threads: env_usize("AIPSO_THREADS", 0),
            seed: 0xBE7C_0001,
            scale_real_world: false,
        }
    }
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// One measured cell.
#[derive(Debug, Clone)]
pub struct Row {
    /// Paper name of the dataset.
    pub dataset: &'static str,
    /// Paper name of the engine.
    pub engine: &'static str,
    /// Keys sorted per repetition.
    pub n: usize,
    /// Mean sorting rate in keys/second.
    pub mean_rate: f64,
    /// Standard deviation of the rate across repetitions.
    pub stddev_rate: f64,
    /// Mean wall-clock seconds per repetition.
    pub mean_secs: f64,
}

/// Run one (dataset, engine) cell.
pub fn run_cell(
    dataset: &'static str,
    engine: SortEngine,
    parallel: bool,
    cfg: &BenchConfig,
) -> Row {
    let spec = datasets::spec(dataset).unwrap_or_else(|| panic!("unknown dataset {dataset}"));
    let n = if cfg.scale_real_world {
        (cfg.n as f64 * spec.size_factor) as usize
    } else {
        cfg.n
    };
    let rates: Vec<f64> = match spec.key_type {
        KeyType::F64 => {
            let base = datasets::generate_f64(dataset, n, cfg.seed).unwrap();
            measure(&base, engine, parallel, cfg)
        }
        KeyType::U64 => {
            let base = datasets::generate_u64(dataset, n, cfg.seed).unwrap();
            measure(&base, engine, parallel, cfg)
        }
    };
    let secs: Vec<f64> = rates.iter().map(|r| n as f64 / r).collect();
    Row {
        dataset: spec.paper_name,
        engine: engine.paper_name(parallel),
        n,
        mean_rate: stats::mean(&rates),
        stddev_rate: stats::stddev(&rates),
        mean_secs: stats::mean(&secs),
    }
}

/// One string-key cell (bench `fig_sequential`, string-key section): the
/// dataset's stream rendered as prefix-encoded strings
/// ([`datasets::generate_str`]) and sorted under the full lexicographic
/// order — ordered-bits prefix partitioning plus the tie-repair pass for
/// prefix-collided keys. Same metric as [`run_cell`], so the rate is
/// directly comparable with the numeric row of the same dataset.
pub fn run_str_cell(
    dataset: &'static str,
    engine: SortEngine,
    parallel: bool,
    cfg: &BenchConfig,
) -> Row {
    let spec = datasets::spec(dataset).unwrap_or_else(|| panic!("unknown dataset {dataset}"));
    let base = datasets::generate_str(spec.name, cfg.n, cfg.seed).unwrap();
    let rates = measure(&base, engine, parallel, cfg);
    let secs: Vec<f64> = rates.iter().map(|r| cfg.n as f64 / r).collect();
    Row {
        dataset: spec.paper_name,
        engine: engine.paper_name(parallel),
        n: cfg.n,
        mean_rate: stats::mean(&rates),
        stddev_rate: stats::stddev(&rates),
        mean_secs: stats::mean(&secs),
    }
}

fn measure<K: SortKey>(
    base: &[K],
    engine: SortEngine,
    parallel: bool,
    cfg: &BenchConfig,
) -> Vec<f64> {
    let mut rates = Vec::with_capacity(cfg.reps);
    for _ in 0..cfg.reps {
        let mut keys = base.to_vec();
        let t0 = std::time::Instant::now();
        if parallel {
            sort_parallel(engine, &mut keys, cfg.threads);
        } else {
            sort_sequential(engine, &mut keys);
        }
        let secs = t0.elapsed().as_secs_f64();
        assert!(crate::is_sorted(&keys), "{engine:?} produced unsorted output");
        rates.push(keys.len() as f64 / secs.max(1e-12));
    }
    rates
}

/// All rows of one paper figure (F1–F6).
pub fn run_figure(group: FigureGroup, parallel: bool, cfg: &BenchConfig) -> Vec<Row> {
    let engines: &[SortEngine] = if parallel {
        &SortEngine::PARALLEL_FIGURES
    } else {
        &SortEngine::SEQUENTIAL_FIGURES
    };
    let mut rows = Vec::new();
    for spec in datasets::ALL.iter().filter(|d| d.group == group) {
        for &engine in engines {
            rows.push(run_cell(spec.name, engine, parallel, cfg));
        }
    }
    rows
}

/// Figures 4–6 on a machine with fewer cores than the paper's 48: the
/// measured *sequential* rate of each engine scaled by the *simulated*
/// speedup of its real top-level partition on `threads` workers (LPT
/// schedule of measured bucket sizes — see [`balance`]). This reproduces
/// the parallel figures' ranking mechanism on any testbed.
pub fn run_figure_simulated(
    group: FigureGroup,
    threads: usize,
    cfg: &BenchConfig,
) -> Vec<Row> {
    let mut rows = Vec::new();
    for spec in datasets::ALL.iter().filter(|d| d.group == group) {
        for &engine in SortEngine::PARALLEL_FIGURES.iter() {
            let row = match spec.key_type {
                KeyType::F64 => {
                    let base = datasets::generate_f64(spec.name, cfg.n, cfg.seed).unwrap();
                    simulated_cell(&base, spec.paper_name, engine, threads, cfg)
                }
                KeyType::U64 => {
                    let base = datasets::generate_u64(spec.name, cfg.n, cfg.seed).unwrap();
                    simulated_cell(&base, spec.paper_name, engine, threads, cfg)
                }
            };
            rows.push(row);
        }
    }
    rows
}

fn simulated_cell<K: SortKey>(
    base: &[K],
    dataset: &'static str,
    engine: SortEngine,
    threads: usize,
    cfg: &BenchConfig,
) -> Row {
    let seq_rates = measure(base, engine, false, cfg);
    let sizes = balance::top_level_bucket_sizes(base, engine, cfg.seed);
    let speedup = balance::simulated_engine_speedup(engine, &sizes, base.len(), threads);
    let rate = stats::mean(&seq_rates) * speedup;
    Row {
        dataset,
        engine: engine.paper_name(true),
        n: base.len(),
        mean_rate: rate,
        stddev_rate: stats::stddev(&seq_rates) * speedup,
        mean_secs: base.len() as f64 / rate,
    }
}

/// Table 2: pivot quality, Random (IPS⁴o-style) vs RMI (Algorithm 4),
/// 255 pivots, on Uniform and Wiki/Edit — exactly the paper's setup.
pub fn table2_pivot_quality(cfg: &BenchConfig) -> Vec<(String, f64, f64)> {
    const PIVOTS: usize = 255;
    let mut out = Vec::new();
    let mut rng = Xoshiro256pp::new(cfg.seed);

    // Uniform (f64)
    {
        let keys = datasets::generate_f64("uniform", cfg.n, cfg.seed).unwrap();
        out.push(pivot_quality_row("Uniform", &keys, PIVOTS, &mut rng));
    }
    // Wiki/Edit (u64)
    {
        let keys = datasets::generate_u64("wiki_edit", cfg.n, cfg.seed).unwrap();
        out.push(pivot_quality_row("Wiki/Edit", &keys, PIVOTS, &mut rng));
    }
    out
}

fn pivot_quality_row<K: SortKey>(
    name: &str,
    keys: &[K],
    n_pivots: usize,
    rng: &mut Xoshiro256pp,
) -> (String, f64, f64) {
    let mut sorted = keys.to_vec();
    sorted.sort_unstable_by(|a, b| a.to_bits_ordered().cmp(&b.to_bits_ordered()));
    // Random pivots the way IPS4o samples (oversample 2, equidistant picks)
    let rp = quality::random_pivots(keys, n_pivots, 2, rng);
    let q_random = quality::pivot_quality_exact(&sorted, &rp);
    // RMI pivots via Algorithm 4, using LearnedSort's training setup.
    // Leaf count scales with the sample so each leaf sees enough points
    // (at the paper's N=1e8 this resolves to the full 1024 leaves).
    let sample_sz = (keys.len() / 50).clamp(4096, 1 << 16).min(keys.len());
    let n_leaves = (sample_sz / 32).clamp(64, 1024);
    let rmi = Rmi::train_from_keys(keys, sample_sz, RmiConfig { n_leaves }, rng);
    let lp = quality::learned_pivots(&rmi, keys, n_pivots + 1);
    let q_rmi = quality::pivot_quality(&sorted, &lp);
    (name.to_string(), q_random, q_rmi)
}

/// One measured external-sort cell (bench `fig_external`).
#[derive(Debug, Clone)]
pub struct ExternalRow {
    /// Paper name of the dataset.
    pub dataset: &'static str,
    /// Run-generation strategy / pipeline variant label.
    pub strategy: String,
    /// Keys sorted.
    pub n: usize,
    /// Wall-clock seconds for the whole external sort.
    pub secs: f64,
    /// Sorting rate in keys/second.
    pub rate: f64,
    /// Spilled runs.
    pub runs: usize,
    /// Runs sorted through the reused RMI.
    pub learned_runs: usize,
    /// Mid-stream model retrains (regime changes the policy recovered).
    pub retrains: usize,
    /// K-way merge passes.
    pub merge_passes: usize,
    /// Worker threads (1 = the serial reference pipeline).
    pub threads: usize,
    /// Final-merge shards (0 = serial loser tree).
    pub merge_shards: usize,
    /// Actual bytes of the run-generation spill files on disk.
    pub spill_bytes: u64,
    /// Bytes the raw fixed-width codec would have spilled for the same
    /// runs (the compression baseline; equal to `spill_bytes` under the
    /// raw codec).
    pub spill_bytes_raw: u64,
    /// Per-phase wall-clock breakdown `(span name, seconds)`, collected
    /// when [`crate::obs`] tracing was enabled while the cell ran; empty
    /// otherwise. Phase seconds are cumulative across threads (overlapped
    /// pipeline stages can sum past the row's wall clock).
    pub phases: Vec<(&'static str, f64)>,
}

/// Aggregate the spans recorded since `mark` into `(phase, seconds)`
/// pairs, ordered by the span taxonomy. The whole-job root is excluded
/// (its total duplicates the row's wall clock).
fn phase_breakdown(mark: usize) -> Vec<(&'static str, f64)> {
    use std::collections::BTreeMap;
    let spans = crate::obs::trace::snapshot();
    let mut acc: BTreeMap<&'static str, u64> = BTreeMap::new();
    for s in spans.get(mark..).unwrap_or(&[]) {
        *acc.entry(s.name).or_default() += s.dur_ns;
    }
    crate::obs::KNOWN_SPANS
        .iter()
        .filter(|&&s| s != crate::obs::S_EXTSORT)
        .filter_map(|&s| acc.remove(s).map(|ns| (s, ns as f64 / 1e9)))
        .collect()
}

/// Measure one external-sort configuration on a dataset file that is
/// already on disk, verifying the output before reporting.
fn external_cell(
    dataset: &'static str,
    kind: crate::key::KeyKind,
    payload: usize,
    input: &std::path::Path,
    output: &std::path::Path,
    strategy: String,
    ext: &crate::external::ExternalConfig,
    n: usize,
) -> ExternalRow {
    // Watermark (not reset) the global trace so the cell's breakdown can
    // be sliced out without clobbering spans owned by anyone else.
    let trace_mark = crate::obs::enabled().then(crate::obs::trace::span_count);
    let (report, secs, ok) =
        crate::external::sort_and_verify(kind, payload, input, output, ext)
            .expect("external sort");
    assert!(ok, "external sort produced unsorted output on {dataset}");
    assert_eq!(report.keys as usize, n, "key count drift on {dataset}");
    let phases = trace_mark.map(phase_breakdown).unwrap_or_default();
    ExternalRow {
        dataset,
        strategy,
        n,
        secs,
        rate: n as f64 / secs.max(1e-12),
        runs: report.runs,
        learned_runs: report.learned_runs,
        retrains: report.retrains,
        merge_passes: report.merge_passes,
        threads: crate::scheduler::effective_threads(ext.threads),
        merge_shards: report.merge_shards,
        spill_bytes: report.spill_bytes,
        spill_bytes_raw: report.spill_bytes_raw,
        phases,
    }
}

/// External-sort scenario: learned run generation (one RMI trained on the
/// first chunk, reused for every run) vs plain IPS⁴o run generation, with
/// identical spill files and loser-tree merge. Inputs are written to disk
/// through the chunked generators, so `cfg.n` can exceed memory.
pub fn run_external_figure(
    names: &[&'static str],
    budget_bytes: usize,
    cfg: &BenchConfig,
) -> Vec<ExternalRow> {
    use crate::external::{ExternalConfig, RunGen};

    let mut rows = Vec::new();
    let dir = std::env::temp_dir();
    for &name in names {
        let spec = datasets::spec(name).unwrap_or_else(|| panic!("unknown dataset {name}"));
        let input = dir.join(format!("aipso-figext-{}-{}.bin", std::process::id(), spec.name));
        let output = dir.join(format!(
            "aipso-figext-{}-{}.out.bin",
            std::process::id(),
            spec.name
        ));
        datasets::write_dataset_file(spec.name, cfg.n, cfg.seed, &input, 1 << 18)
            .expect("chunked dataset write");
        for (run_gen, strategy) in [
            (RunGen::LearnedReuse, "learned runs (RMI reuse)"),
            (RunGen::Ips4o, "IPS4o runs"),
        ] {
            let ext = ExternalConfig {
                memory_budget: budget_bytes,
                run_gen,
                threads: cfg.threads,
                ..ExternalConfig::default()
            };
            rows.push(external_cell(
                spec.paper_name,
                spec.key_type.kind(),
                0,
                &input,
                &output,
                strategy.to_string(),
                &ext,
                cfg.n,
            ));
        }
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&output);
    }
    rows
}

/// Serial-vs-parallel sweep of the learned external pipeline: one row per
/// (dataset, thread count). `threads = 1` is the serial reference (serial
/// chunk loop + serial loser-tree merge); `threads >= 2` runs overlapped
/// chunk IO plus the RMI-sharded final merge. Identical budget and run
/// strategy everywhere, so the delta isolates pipeline parallelism.
pub fn run_external_thread_sweep(
    names: &[&'static str],
    budget_bytes: usize,
    thread_counts: &[usize],
    cfg: &BenchConfig,
) -> Vec<ExternalRow> {
    use crate::external::ExternalConfig;

    let mut rows = Vec::new();
    let dir = std::env::temp_dir();
    for &name in names {
        let spec = datasets::spec(name).unwrap_or_else(|| panic!("unknown dataset {name}"));
        let input = dir.join(format!(
            "aipso-extsweep-{}-{}.bin",
            std::process::id(),
            spec.name
        ));
        let output = dir.join(format!(
            "aipso-extsweep-{}-{}.out.bin",
            std::process::id(),
            spec.name
        ));
        datasets::write_dataset_file(spec.name, cfg.n, cfg.seed, &input, 1 << 18)
            .expect("chunked dataset write");
        for &threads in thread_counts {
            let ext = ExternalConfig {
                memory_budget: budget_bytes,
                threads: threads.max(1),
                ..ExternalConfig::default()
            };
            let strategy = if threads <= 1 {
                "serial pipeline".to_string()
            } else {
                format!("parallel pipeline ({threads}t)")
            };
            rows.push(external_cell(
                spec.paper_name,
                spec.key_type.kind(),
                0,
                &input,
                &output,
                strategy,
                &ext,
                cfg.n,
            ));
        }
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&output);
    }
    rows
}

/// Regime-shift scenario: one stream concatenating equal thirds of
/// `uniform` → `lognormal` → `zipf` (a mid-stream regime change twice
/// over), sorted by the learned pipeline with the rolling retrain policy
/// enabled vs disabled. Everything else — budget, threads, merge — is
/// identical, so the delta isolates [`crate::external::RetrainPolicy`]:
/// with retraining off, every post-shift chunk falls back to IPS⁴o and
/// the shard cuts stay pinned to the first regime; with it on, run
/// generation re-learns each tractable regime (zipf stays on the fallback
/// by design — Algorithm 5's duplicate guard blocks its model) and the
/// merge cuts follow the keys-weighted epoch mixture.
pub fn run_external_regime_shift(budget_bytes: usize, cfg: &BenchConfig) -> Vec<ExternalRow> {
    use crate::external::{ExternalConfig, RetrainPolicy, RunWriter};

    let dir = std::env::temp_dir();
    let input = dir.join(format!("aipso-figregime-{}.bin", std::process::id()));
    let output = dir.join(format!("aipso-figregime-{}.out.bin", std::process::id()));
    let regimes = ["uniform", "lognormal", "zipf"];
    let per = (cfg.n / regimes.len()).max(1);
    let n = per * regimes.len();
    {
        let mut w = RunWriter::<f64>::create(input.clone(), 1 << 16).expect("create stream");
        for name in regimes {
            let mut gen = datasets::chunked_f64(name, per, cfg.seed).expect("regime generator");
            while let Some(chunk) = gen.next_chunk(1 << 16) {
                w.write_slice(&chunk).expect("write regime chunk");
            }
        }
        w.finish().expect("finish stream");
    }

    let mut rows = Vec::new();
    for (retrain, label) in [
        (RetrainPolicy::default(), "retrain on (drift recovery)"),
        (RetrainPolicy::disabled(), "retrain off (permanent fallback)"),
    ] {
        let ext = ExternalConfig {
            memory_budget: budget_bytes,
            threads: cfg.threads,
            retrain,
            ..ExternalConfig::default()
        };
        rows.push(external_cell(
            "Uniform→LogNormal→Zipf",
            crate::key::KeyKind::F64,
            0,
            &input,
            &output,
            label.to_string(),
            &ext,
            n,
        ));
    }
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
    rows
}

/// Key-width sweep of the learned external pipeline: each dataset sorted
/// at its native 8-byte width and narrowed to 4 bytes (`f64 → f32`,
/// `u64 → u32`, the `gen --width 4` files). Identical key count, budget
/// and pipeline, so the delta isolates the spill width: 4-byte runs move
/// half the bytes per key through disk and fit twice the keys per chunk.
pub fn run_external_width_sweep(
    names: &[&'static str],
    budget_bytes: usize,
    cfg: &BenchConfig,
) -> Vec<ExternalRow> {
    use crate::external::ExternalConfig;

    let mut rows = Vec::new();
    let dir = std::env::temp_dir();
    for &name in names {
        let spec = datasets::spec(name).unwrap_or_else(|| panic!("unknown dataset {name}"));
        let input = dir.join(format!(
            "aipso-extwidth-{}-{}.bin",
            std::process::id(),
            spec.name
        ));
        let output = dir.join(format!(
            "aipso-extwidth-{}-{}.out.bin",
            std::process::id(),
            spec.name
        ));
        for width in [8usize, 4] {
            let kind =
                datasets::write_dataset_file_width(spec.name, cfg.n, cfg.seed, &input, 1 << 18, width)
                    .expect("chunked dataset write");
            let ext = ExternalConfig {
                memory_budget: budget_bytes,
                threads: cfg.threads,
                ..ExternalConfig::default()
            };
            rows.push(external_cell(
                spec.paper_name,
                kind,
                0,
                &input,
                &output,
                format!("{}-byte keys ({})", width, kind.name()),
                &ext,
                cfg.n,
            ));
        }
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&output);
    }
    rows
}

/// Payload-width sweep of the learned external pipeline: each dataset
/// sorted as bare keys and as records carrying 8- and 64-byte row-id
/// payloads (the v4 record spill format, `gen --payload`). Identical key
/// count, budget and pipeline, so the deltas isolate the payload lane:
/// spill bytes grow by exactly `payload` bytes per entry (visible in the
/// spill column) and fewer records fit per run-generation chunk.
pub fn run_external_payload_sweep(
    names: &[&'static str],
    budget_bytes: usize,
    cfg: &BenchConfig,
) -> Vec<ExternalRow> {
    use crate::external::ExternalConfig;

    let mut rows = Vec::new();
    let dir = std::env::temp_dir();
    for &name in names {
        let spec = datasets::spec(name).unwrap_or_else(|| panic!("unknown dataset {name}"));
        let input = dir.join(format!(
            "aipso-extpayload-{}-{}.bin",
            std::process::id(),
            spec.name
        ));
        let output = dir.join(format!(
            "aipso-extpayload-{}-{}.out.bin",
            std::process::id(),
            spec.name
        ));
        for payload in crate::key::DISPATCH_PAYLOADS {
            let kind = datasets::write_dataset_file_ext(
                spec.name,
                cfg.n,
                cfg.seed,
                &input,
                1 << 18,
                8,
                false,
                payload,
            )
            .expect("chunked dataset write");
            let ext = ExternalConfig {
                memory_budget: budget_bytes,
                threads: cfg.threads,
                ..ExternalConfig::default()
            };
            rows.push(external_cell(
                spec.paper_name,
                kind,
                payload,
                &input,
                &output,
                format!("{payload} B payload"),
                &ext,
                cfg.n,
            ));
        }
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&output);
    }
    rows
}

/// Spill-codec sweep of the learned external pipeline: each dataset
/// sorted with the raw fixed-width spill codec vs the delta+varint block
/// codec (`ExternalConfig::spill_codec`). Identical key count, budget,
/// threads and merge — and *byte-identical outputs*, since the output is
/// always raw — so the rate delta isolates the spill IO volume, and the
/// spill column shows the compression the merge's reads ran on.
pub fn run_external_codec_sweep(
    names: &[&'static str],
    budget_bytes: usize,
    cfg: &BenchConfig,
) -> Vec<ExternalRow> {
    use crate::external::{ExternalConfig, SpillCodec};

    let mut rows = Vec::new();
    let dir = std::env::temp_dir();
    for &name in names {
        let spec = datasets::spec(name).unwrap_or_else(|| panic!("unknown dataset {name}"));
        let input = dir.join(format!(
            "aipso-extcodec-{}-{}.bin",
            std::process::id(),
            spec.name
        ));
        let output = dir.join(format!(
            "aipso-extcodec-{}-{}.out.bin",
            std::process::id(),
            spec.name
        ));
        datasets::write_dataset_file(spec.name, cfg.n, cfg.seed, &input, 1 << 18)
            .expect("chunked dataset write");
        for codec in [SpillCodec::Raw, SpillCodec::Delta] {
            let ext = ExternalConfig {
                memory_budget: budget_bytes,
                threads: cfg.threads,
                spill_codec: codec,
                ..ExternalConfig::default()
            };
            rows.push(external_cell(
                spec.paper_name,
                spec.key_type.kind(),
                0,
                &input,
                &output,
                format!("{} spill codec", codec.name()),
                &ext,
                cfg.n,
            ));
        }
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&output);
    }
    rows
}

/// IO-substrate sweep of the learned external pipeline: the sync
/// reference backend vs the submission-queue pool backend, with spill
/// runs striped across one or two directories and — on the widest
/// variant — `O_DIRECT` run-generation spills (silently buffered where
/// the filesystem refuses, e.g. tmpfs). Identical key count, budget,
/// threads, codec and merge, *and byte-identical outputs* (the substrate
/// is pure transport), so the rate delta isolates how spill IO is issued
/// and where it lands.
pub fn run_external_io_sweep(
    names: &[&'static str],
    budget_bytes: usize,
    cfg: &BenchConfig,
) -> Vec<ExternalRow> {
    use crate::external::{ExternalConfig, IoBackendKind};
    use std::path::PathBuf;

    let mut rows = Vec::new();
    let dir = std::env::temp_dir();
    let stripe_a = dir.join(format!("aipso-extio-stripe-a-{}", std::process::id()));
    let stripe_b = dir.join(format!("aipso-extio-stripe-b-{}", std::process::id()));
    for &name in names {
        let spec = datasets::spec(name).unwrap_or_else(|| panic!("unknown dataset {name}"));
        let input = dir.join(format!(
            "aipso-extio-{}-{}.bin",
            std::process::id(),
            spec.name
        ));
        let output = dir.join(format!(
            "aipso-extio-{}-{}.out.bin",
            std::process::id(),
            spec.name
        ));
        datasets::write_dataset_file(spec.name, cfg.n, cfg.seed, &input, 1 << 18)
            .expect("chunked dataset write");
        let one: Vec<PathBuf> = vec![stripe_a.clone()];
        let two: Vec<PathBuf> = vec![stripe_a.clone(), stripe_b.clone()];
        let variants: [(IoBackendKind, &Vec<PathBuf>, bool, &str); 4] = [
            (IoBackendKind::Sync, &one, false, "sync backend, 1 spill dir"),
            (IoBackendKind::Pool, &one, false, "pool backend, 1 spill dir"),
            (IoBackendKind::Pool, &two, false, "pool backend, 2-dir stripe"),
            (IoBackendKind::Pool, &two, true, "pool backend, 2-dir stripe, O_DIRECT"),
        ];
        for (io_backend, spill_dirs, direct_io, label) in variants {
            let ext = ExternalConfig {
                memory_budget: budget_bytes,
                threads: cfg.threads,
                io_backend,
                spill_dirs: spill_dirs.clone(),
                direct_io,
                ..ExternalConfig::default()
            };
            rows.push(external_cell(
                spec.paper_name,
                spec.key_type.kind(),
                0,
                &input,
                &output,
                label.to_string(),
                &ext,
                cfg.n,
            ));
        }
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&output);
    }
    let _ = std::fs::remove_dir_all(&stripe_a);
    let _ = std::fs::remove_dir_all(&stripe_b);
    rows
}

/// Human-readable spill cell: on-disk bytes + ratio to the raw baseline.
fn spill_cell(bytes: u64, raw: u64) -> String {
    format!(
        "{:.1} MiB ({:.2}x)",
        bytes as f64 / (1 << 20) as f64,
        bytes as f64 / raw.max(1) as f64
    )
}

/// Human-readable phase cell: each traced phase as a share of `secs`
/// ("—" when the row ran untraced).
fn phase_share_cell(phases: &[(&'static str, f64)], secs: f64) -> String {
    if phases.is_empty() {
        return "—".to_string();
    }
    phases
        .iter()
        .map(|(name, s)| format!("{} {:.0}%", name, 100.0 * s / secs.max(1e-12)))
        .collect::<Vec<_>>()
        .join(", ")
}

fn phase_cell(r: &ExternalRow) -> String {
    phase_share_cell(&r.phases, r.secs)
}

/// One measured cell of the in-memory duplicate sweep (bench
/// `fig_sequential`, LearnedSort 2.0 section).
#[derive(Debug, Clone)]
pub struct DupRow {
    /// Sweep label: base distribution + duplicate share.
    pub dataset: String,
    /// Engine / partition-scheme label.
    pub engine: &'static str,
    /// Keys sorted per repetition.
    pub n: usize,
    /// Fraction of keys overwritten with the heavy values.
    pub dup_fraction: f64,
    /// Mean sorting rate in keys/second.
    pub mean_rate: f64,
    /// Mean wall-clock seconds per repetition.
    pub mean_secs: f64,
    /// Mean per-phase seconds per repetition `(span name, seconds)`,
    /// collected when [`crate::obs`] tracing was enabled while the cell
    /// ran; empty otherwise. The fragmented scheme additionally reports
    /// its `frag-partition` / `frag-compact` spans here.
    pub phases: Vec<(&'static str, f64)>,
}

/// In-memory duplicate sweep: uniform keys with a swept share of them
/// overwritten by two heavy values — LearnedSort's adversarial case.
/// Each fraction is sorted by the 2.0 fragmented scheme (equality
/// buckets), the 1.x block scheme (spill bucket) and `std::sort`;
/// identical inputs per fraction, so the deltas isolate the partition
/// scheme's duplicate handling.
pub fn run_dup_sweep(fractions: &[f64], cfg: &BenchConfig) -> Vec<DupRow> {
    use crate::learned_sort::{self, LearnedSortConfig};

    let mut rows = Vec::new();
    for &frac in fractions {
        let mut base = datasets::generate_f64("uniform", cfg.n, cfg.seed).unwrap();
        let mut rng = Xoshiro256pp::new(cfg.seed ^ (frac * 1e6) as u64);
        for k in base.iter_mut() {
            if rng.uniform(0.0, 1.0) < frac {
                *k = if rng.next_u64() % 2 == 0 { 123.25 } else { 987.5 };
            }
        }
        let v2 = LearnedSortConfig::default();
        let v1 = LearnedSortConfig::v1();
        let cells: [(&'static str, Option<&LearnedSortConfig>); 3] = [
            ("LearnedSort 2.0 (fragments)", Some(&v2)),
            ("LearnedSort (blocks)", Some(&v1)),
            ("std::sort", None),
        ];
        for (label, ls) in cells {
            // Watermark (not reset) the global trace — see external_cell.
            let mark = crate::obs::enabled().then(crate::obs::trace::span_count);
            let mut secs_all = Vec::with_capacity(cfg.reps);
            for _ in 0..cfg.reps {
                let mut keys = base.clone();
                let t0 = std::time::Instant::now();
                match ls {
                    Some(c) => learned_sort::sort_cfg(&mut keys, c),
                    None => sort_sequential(SortEngine::StdSort, &mut keys),
                }
                secs_all.push(t0.elapsed().as_secs_f64());
                assert!(crate::is_sorted(&keys), "{label} produced unsorted output");
            }
            let reps = cfg.reps.max(1) as f64;
            let phases: Vec<(&'static str, f64)> = mark
                .map(phase_breakdown)
                .unwrap_or_default()
                .into_iter()
                .map(|(name, s)| (name, s / reps))
                .collect();
            let mean_secs = stats::mean(&secs_all);
            rows.push(DupRow {
                dataset: format!("uniform + {:.0}% dups", frac * 100.0),
                engine: label,
                n: base.len(),
                dup_fraction: frac,
                mean_rate: base.len() as f64 / mean_secs.max(1e-12),
                mean_secs,
                phases,
            });
        }
    }
    rows
}

/// Render duplicate-sweep rows as a markdown table.
pub fn render_dup_rows(title: &str, rows: &[DupRow]) -> String {
    let mut out = format!("## {title}\n\n");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.dataset.clone(),
                r.engine.to_string(),
                fmt::keys(r.n),
                format!("{:.0}%", r.dup_fraction * 100.0),
                fmt::rate(r.mean_rate),
                fmt::secs(r.mean_secs),
                phase_share_cell(&r.phases, r.mean_secs),
            ]
        })
        .collect();
    out.push_str(&fmt::markdown_table(
        &["dataset", "engine", "n", "dups", "rate", "time", "phases"],
        &table,
    ));
    out
}

/// One measured cell of the parallel-LearnedSort thread sweep (bench
/// `fig_parallel`, LearnedSort 2.0 section).
#[derive(Debug, Clone)]
pub struct LearnedParRow {
    /// Paper name of the dataset.
    pub dataset: &'static str,
    /// Worker threads for the cell (1 = the sequential fragmented path,
    /// which the parallel formulation must reproduce byte-for-byte).
    pub threads: usize,
    /// Keys sorted per repetition.
    pub n: usize,
    /// Mean sorting rate in keys/second.
    pub mean_rate: f64,
    /// Standard deviation of the rate across repetitions.
    pub stddev_rate: f64,
    /// Speedup over the same dataset's first (single-thread) row.
    pub speedup: f64,
    /// Mean per-phase seconds per repetition `(span name, seconds)`,
    /// collected when [`crate::obs`] tracing was enabled while the cell
    /// ran; empty otherwise. Parallel cells additionally report the
    /// `frag-par-sweep` / `frag-par-merge` spans here.
    pub phases: Vec<(&'static str, f64)>,
}

/// Thread sweep of the parallel fragmented LearnedSort
/// ([`crate::learned_sort::sort_par`]): each dataset is sorted at every
/// requested thread count on identical inputs, with the single-thread
/// cell as the speedup baseline. The paper benchmarks LearnedSort
/// sequentially only; this sweep measures the repo's thread-parallel
/// formulation (per-thread fragment chains stitched by a deterministic
/// merge/compaction), whose output is byte-identical to the sequential
/// engine at every thread count.
pub fn run_learned_thread_sweep(
    names: &[&'static str],
    threads: &[usize],
    cfg: &BenchConfig,
) -> Vec<LearnedParRow> {
    let mut rows = Vec::new();
    for &name in names {
        let spec = datasets::spec(name).unwrap_or_else(|| panic!("unknown dataset {name}"));
        let mut base_rate = f64::NAN;
        for &t in threads {
            let (rates, phases) = match spec.key_type {
                KeyType::F64 => {
                    let base = datasets::generate_f64(name, cfg.n, cfg.seed).unwrap();
                    measure_learned_par(&base, t, cfg)
                }
                KeyType::U64 => {
                    let base = datasets::generate_u64(name, cfg.n, cfg.seed).unwrap();
                    measure_learned_par(&base, t, cfg)
                }
            };
            let mean_rate = stats::mean(&rates);
            if base_rate.is_nan() {
                base_rate = mean_rate;
            }
            rows.push(LearnedParRow {
                dataset: spec.paper_name,
                threads: t,
                n: cfg.n,
                mean_rate,
                stddev_rate: stats::stddev(&rates),
                speedup: mean_rate / base_rate.max(1e-12),
                phases,
            });
        }
    }
    rows
}

fn measure_learned_par<K: SortKey>(
    base: &[K],
    threads: usize,
    cfg: &BenchConfig,
) -> (Vec<f64>, Vec<(&'static str, f64)>) {
    use crate::learned_sort;
    // Watermark (not reset) the global trace — see external_cell.
    let mark = crate::obs::enabled().then(crate::obs::trace::span_count);
    let mut rates = Vec::with_capacity(cfg.reps);
    for _ in 0..cfg.reps {
        let mut keys = base.to_vec();
        let t0 = std::time::Instant::now();
        if threads <= 1 {
            learned_sort::sort(&mut keys);
        } else {
            learned_sort::sort_par(&mut keys, threads);
        }
        let secs = t0.elapsed().as_secs_f64();
        assert!(
            crate::is_sorted(&keys),
            "sort_par(t={threads}) produced unsorted output"
        );
        rates.push(keys.len() as f64 / secs.max(1e-12));
    }
    let reps = cfg.reps.max(1) as f64;
    let phases = mark
        .map(phase_breakdown)
        .unwrap_or_default()
        .into_iter()
        .map(|(name, s)| (name, s / reps))
        .collect();
    (rates, phases)
}

/// Render thread-sweep rows as a markdown table.
pub fn render_learned_par_rows(title: &str, rows: &[LearnedParRow]) -> String {
    let mut out = format!("## {title}\n\n");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.dataset.to_string(),
                if r.threads == 1 {
                    "1 (sequential)".to_string()
                } else {
                    r.threads.to_string()
                },
                fmt::keys(r.n),
                fmt::rate(r.mean_rate),
                format!("±{}", fmt::rate(r.stddev_rate)),
                format!("{:.2}x", r.speedup),
                phase_share_cell(&r.phases, r.n as f64 / r.mean_rate.max(1e-12)),
            ]
        })
        .collect();
    out.push_str(&fmt::markdown_table(
        &["dataset", "threads", "n", "rate", "stddev", "speedup", "phases"],
        &table,
    ));
    out
}

/// Render external rows as a markdown table.
pub fn render_external_rows(title: &str, rows: &[ExternalRow]) -> String {
    let mut out = format!("## {title}\n\n");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.dataset.to_string(),
                r.strategy.clone(),
                fmt::keys(r.n),
                fmt::rate(r.rate),
                fmt::secs(r.secs),
                format!("{} ({} learned)", r.runs, r.learned_runs),
                r.retrains.to_string(),
                r.merge_passes.to_string(),
                if r.merge_shards == 0 {
                    "serial".to_string()
                } else {
                    format!("{} shards", r.merge_shards)
                },
                spill_cell(r.spill_bytes, r.spill_bytes_raw),
                phase_cell(r),
            ]
        })
        .collect();
    out.push_str(&fmt::markdown_table(
        &[
            "dataset",
            "pipeline",
            "n",
            "rate",
            "time",
            "runs",
            "retrains",
            "merge passes",
            "final merge",
            "spill",
            "phases",
        ],
        &table,
    ));
    out
}

/// Render figure rows as a paper-style markdown table (one block per
/// dataset, engines as rows).
pub fn render_rows(title: &str, rows: &[Row]) -> String {
    let mut out = format!("## {title}\n\n");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.dataset.to_string(),
                r.engine.to_string(),
                fmt::keys(r.n),
                fmt::rate(r.mean_rate),
                format!("±{}", fmt::rate(r.stddev_rate)),
                fmt::secs(r.mean_secs),
            ]
        })
        .collect();
    out.push_str(&fmt::markdown_table(
        &["dataset", "engine", "n", "rate", "stddev", "time"],
        &table,
    ));
    // winner per dataset — the paper's headline statistic
    out.push_str("\nwinners: ");
    let mut ds: Vec<&str> = rows.iter().map(|r| r.dataset).collect();
    ds.dedup();
    for d in ds {
        let best = rows
            .iter()
            .filter(|r| r.dataset == d)
            .max_by(|a, b| a.mean_rate.partial_cmp(&b.mean_rate).unwrap())
            .unwrap();
        out.push_str(&format!("{} -> {}; ", d, best.engine));
    }
    out.push('\n');
    out
}

/// Count per-engine wins (the paper reports "fastest in X of 14").
pub fn count_wins(rows: &[Row]) -> Vec<(&'static str, usize)> {
    use std::collections::BTreeMap;
    let mut wins: BTreeMap<&'static str, usize> = BTreeMap::new();
    let mut ds: Vec<&str> = rows.iter().map(|r| r.dataset).collect();
    ds.dedup();
    for d in ds {
        let best = rows
            .iter()
            .filter(|r| r.dataset == d)
            .max_by(|a, b| a.mean_rate.partial_cmp(&b.mean_rate).unwrap())
            .unwrap();
        *wins.entry(best.engine).or_default() += 1;
    }
    wins.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> BenchConfig {
        BenchConfig {
            n: 20_000,
            reps: 1,
            threads: 2,
            seed: 1,
            scale_real_world: false,
        }
    }

    #[test]
    fn run_cell_produces_rate() {
        let row = run_cell("uniform", SortEngine::StdSort, false, &tiny());
        assert!(row.mean_rate > 0.0);
        assert_eq!(row.dataset, "Uniform");
        assert_eq!(row.engine, "std::sort");
    }

    #[test]
    fn table2_shape_holds_at_small_n() {
        let rows = table2_pivot_quality(&BenchConfig {
            n: 100_000,
            ..tiny()
        });
        assert_eq!(rows.len(), 2);
        for (name, q_random, q_rmi) in &rows {
            assert!(
                q_rmi < q_random,
                "{name}: RMI pivots ({q_rmi}) must beat random ({q_random})"
            );
        }
    }

    #[test]
    fn external_figure_smoke() {
        let cfg = BenchConfig {
            n: 40_000,
            ..tiny()
        };
        // 3 * 8Ki-key budget: the pipelined chunks (a third of it, threads=2)
        // still clear min_learned_chunk → ≥4 runs per dataset, model engaged
        let rows = run_external_figure(&["uniform", "nyc_pickup"], 3 * 8192 * 8, &cfg);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.rate > 0.0);
            assert!(r.runs >= 4, "{}: runs={}", r.dataset, r.runs);
        }
        // learned strategy must actually use the model on smooth data
        let learned_uniform = rows
            .iter()
            .find(|r| r.dataset == "Uniform" && r.strategy.starts_with("learned"))
            .unwrap();
        assert!(learned_uniform.learned_runs > 0);
        let report = render_external_rows("t", &rows);
        assert!(report.contains("Uniform"));
        assert!(report.contains("merge passes"));
    }

    #[test]
    fn external_rows_carry_phase_breakdowns_when_tracing() {
        let _l = crate::obs::test_lock();
        crate::obs::reset();
        crate::obs::set_enabled(true);
        let cfg = BenchConfig {
            n: 40_000,
            ..tiny()
        };
        let rows = run_external_figure(&["uniform"], 3 * 8192 * 8, &cfg);
        crate::obs::set_enabled(false);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(
                !r.phases.is_empty(),
                "{}: traced rows carry a phase breakdown",
                r.strategy
            );
            let names: Vec<&str> = r.phases.iter().map(|p| p.0).collect();
            for s in crate::obs::BASE_EXTSORT_SPANS {
                assert!(names.contains(s), "{s} missing from {names:?}");
            }
            assert!(
                !names.contains(&crate::obs::S_EXTSORT),
                "the whole-job root is excluded from the breakdown"
            );
        }
        let report = render_external_rows("traced", &rows);
        assert!(report.contains("phases"));
        assert!(report.contains("chunk-read"));
        // untraced rows render the placeholder cell
        let quiet = run_external_figure(&["uniform"], 3 * 8192 * 8, &cfg);
        assert!(quiet.iter().all(|r| r.phases.is_empty()));
        assert!(render_external_rows("quiet", &quiet).contains("—"));
    }

    #[test]
    fn width_sweep_rows_halve_the_narrow_side() {
        let cfg = BenchConfig {
            n: 60_000,
            ..tiny()
        };
        // budget in bytes: 8-byte chunks of 8192 keys, 4-byte of 16384
        let rows = run_external_width_sweep(&["uniform"], 3 * 8192 * 8, &cfg);
        assert_eq!(rows.len(), 2);
        assert!(rows[0].strategy.contains("8-byte"));
        assert!(rows[1].strategy.contains("4-byte"));
        assert_eq!(rows[0].n, rows[1].n, "equal key counts at both widths");
        assert!(
            rows[1].runs * 2 == rows[0].runs || rows[1].runs * 2 == rows[0].runs + 1,
            "half the runs at width 4 ({} vs {})",
            rows[1].runs,
            rows[0].runs
        );
        for r in &rows {
            assert!(r.rate > 0.0);
        }
    }

    #[test]
    fn payload_sweep_spill_bytes_grow_with_the_lane() {
        let cfg = BenchConfig {
            n: 40_000,
            ..tiny()
        };
        let rows = run_external_payload_sweep(&["uniform"], 3 * 8192 * 8, &cfg);
        assert_eq!(rows.len(), 3, "one row per payload width");
        assert!(rows[0].strategy.starts_with("0 B"));
        assert!(rows[1].strategy.starts_with("8 B"));
        assert!(rows[2].strategy.starts_with("64 B"));
        for r in &rows {
            assert_eq!(r.n, cfg.n, "payloads never change the key count");
            assert!(r.rate > 0.0);
        }
        // the raw spill accounting must reflect the payload bytes: every
        // spilled entry is key + lane wide (plus one header per run file)
        let hdr = crate::external::spill::HEADER_LEN as u64;
        for (r, entry) in rows.iter().zip([8u64, 16, 72]) {
            assert_eq!(
                r.spill_bytes_raw,
                cfg.n as u64 * entry + r.runs as u64 * hdr,
                "raw spill bytes at {} B/entry",
                entry
            );
        }
        let report = render_external_rows("payloads", &rows);
        assert!(report.contains("64 B payload"));
    }

    #[test]
    fn str_cell_sorts_prefix_tied_strings() {
        let row = run_str_cell("wiki_edit", SortEngine::Aips2o, false, &tiny());
        assert!(row.mean_rate > 0.0);
        assert_eq!(row.dataset, "Wiki/Edit");
        assert_eq!(row.engine, "AI1S2o");
    }

    #[test]
    fn codec_sweep_compresses_dup_heavy_spills() {
        let cfg = BenchConfig {
            n: 60_000,
            ..tiny()
        };
        // wiki_edit: duplicate-heavy sorted timestamps — the delta codec's
        // best case (small varint gaps + run-length dup escapes)
        let rows = run_external_codec_sweep(&["wiki_edit"], 3 * 8192 * 8, &cfg);
        assert_eq!(rows.len(), 2);
        let raw = &rows[0];
        let delta = &rows[1];
        assert!(raw.strategy.starts_with("raw"));
        assert!(delta.strategy.starts_with("delta"));
        assert_eq!(
            raw.spill_bytes, raw.spill_bytes_raw,
            "raw codec spills at the fixed-width baseline"
        );
        assert_eq!(raw.spill_bytes_raw, delta.spill_bytes_raw, "same baseline");
        assert!(
            delta.spill_bytes * 2 < delta.spill_bytes_raw,
            "dup-heavy delta spill must compress ({} vs {})",
            delta.spill_bytes,
            delta.spill_bytes_raw
        );
        let report = render_external_rows("codec", &rows);
        assert!(report.contains("spill"));
        assert!(report.contains("0."), "delta ratio below 1 rendered");
    }

    #[test]
    fn io_sweep_rows_cover_every_substrate_variant() {
        let cfg = BenchConfig {
            n: 60_000,
            ..tiny()
        };
        // external_cell verifies each output, so the four variants passing
        // at all pins the substrate's byte-transparency on a real dataset
        let rows = run_external_io_sweep(&["uniform"], 3 * 8192 * 8, &cfg);
        assert_eq!(rows.len(), 4);
        assert!(rows[0].strategy.starts_with("sync"));
        assert!(rows[1].strategy.starts_with("pool"));
        assert!(rows[2].strategy.contains("2-dir"));
        assert!(rows[3].strategy.contains("O_DIRECT"));
        for r in &rows {
            assert_eq!(r.n, rows[0].n);
            assert_eq!(r.runs, rows[0].runs, "same chunking on every backend");
            assert!(r.rate > 0.0);
        }
    }

    #[test]
    fn regime_shift_rows_isolate_the_retrain_policy() {
        let cfg = BenchConfig {
            n: 120_000,
            ..tiny()
        };
        // threads=2 ⇒ 8Ki-key pipelined chunks: ~15 chunks across the
        // three regimes, several of them after each shift
        let rows = run_external_regime_shift(3 * 8192 * 8, &cfg);
        assert_eq!(rows.len(), 2);
        let on = &rows[0];
        let off = &rows[1];
        assert!(on.strategy.starts_with("retrain on"));
        assert!(off.strategy.starts_with("retrain off"));
        assert!(on.retrains >= 1, "the regime shifts must trigger a retrain");
        assert_eq!(off.retrains, 0, "disabled policy must never retrain");
        assert!(
            on.learned_runs > off.learned_runs,
            "retraining must recover learned runs ({} !> {})",
            on.learned_runs,
            off.learned_runs
        );
        let report = render_external_rows("regime shift", &rows);
        assert!(report.contains("retrains"));
        assert!(report.contains("Uniform→LogNormal→Zipf"));
    }

    #[test]
    fn thread_sweep_serial_vs_parallel_rows() {
        let cfg = BenchConfig {
            n: 40_000,
            ..tiny()
        };
        let rows = run_external_thread_sweep(&["uniform"], 8192 * 8, &[1, 2], &cfg);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].threads, 1);
        assert_eq!(rows[0].strategy, "serial pipeline");
        assert_eq!(rows[0].merge_shards, 0, "serial never shards");
        assert_eq!(rows[1].threads, 2);
        assert!(rows[1].strategy.starts_with("parallel"));
        for r in &rows {
            assert!(r.rate > 0.0);
            assert!(r.runs >= 2, "{}: runs={}", r.strategy, r.runs);
        }
    }

    #[test]
    fn dup_sweep_rows_cover_both_schemes() {
        // hold the obs lock so no concurrent test enables tracing — the
        // placeholder assertion below needs genuinely untraced rows
        let _l = crate::obs::test_lock();
        let cfg = BenchConfig {
            n: 60_000,
            ..tiny()
        };
        let rows = run_dup_sweep(&[0.0, 0.9], &cfg);
        assert_eq!(rows.len(), 6, "3 engines per fraction");
        for r in &rows {
            assert!(r.mean_rate > 0.0, "{} / {}", r.dataset, r.engine);
            assert_eq!(r.n, 60_000);
        }
        let report = render_dup_rows("dups", &rows);
        assert!(report.contains("LearnedSort 2.0 (fragments)"));
        assert!(report.contains("LearnedSort (blocks)"));
        assert!(report.contains("90%"));
        assert!(report.contains("—"), "untraced rows render the placeholder");
    }

    #[test]
    fn dup_sweep_traces_the_fragment_phases() {
        let _l = crate::obs::test_lock();
        crate::obs::reset();
        crate::obs::set_enabled(true);
        let cfg = BenchConfig {
            n: 60_000,
            ..tiny()
        };
        let rows = run_dup_sweep(&[0.9], &cfg);
        crate::obs::set_enabled(false);
        let v2 = rows.iter().find(|r| r.engine.contains("fragments")).unwrap();
        let names: Vec<&str> = v2.phases.iter().map(|p| p.0).collect();
        assert!(names.contains(&crate::obs::S_FRAG_PARTITION), "{names:?}");
        assert!(names.contains(&crate::obs::S_FRAG_COMPACT), "{names:?}");
        let v1 = rows.iter().find(|r| r.engine.contains("blocks")).unwrap();
        let v1names: Vec<&str> = v1.phases.iter().map(|p| p.0).collect();
        assert!(
            !v1names.contains(&crate::obs::S_FRAG_PARTITION),
            "the block scheme must not record fragment spans: {v1names:?}"
        );
    }

    #[test]
    fn learned_thread_sweep_reports_speedup_column() {
        // hold the obs lock so no concurrent test enables tracing — the
        // placeholder assertion below needs genuinely untraced rows
        let _l = crate::obs::test_lock();
        let cfg = BenchConfig {
            n: 60_000,
            ..tiny()
        };
        let rows = run_learned_thread_sweep(&["uniform", "wiki_edit"], &[1, 2], &cfg);
        assert_eq!(rows.len(), 4, "2 datasets x 2 thread counts");
        for r in &rows {
            assert!(r.mean_rate > 0.0, "{} t={}", r.dataset, r.threads);
            assert_eq!(r.n, 60_000);
        }
        assert_eq!(rows[0].threads, 1);
        assert!(
            (rows[0].speedup - 1.0).abs() < 1e-9,
            "the single-thread row is its own baseline"
        );
        assert_eq!(rows[2].dataset, "Wiki/Edit", "u64 datasets sweep too");
        let report = render_learned_par_rows("threads", &rows);
        assert!(report.contains("speedup"));
        assert!(report.contains("1 (sequential)"));
        assert!(report.contains("—"), "untraced rows render the placeholder");
    }

    #[test]
    fn learned_thread_sweep_traces_the_frag_par_phases() {
        let _l = crate::obs::test_lock();
        crate::obs::reset();
        crate::obs::set_enabled(true);
        let cfg = BenchConfig {
            n: 120_000,
            ..tiny()
        };
        let rows = run_learned_thread_sweep(&["uniform"], &[1, 4], &cfg);
        crate::obs::set_enabled(false);
        let par = rows.iter().find(|r| r.threads == 4).unwrap();
        let names: Vec<&str> = par.phases.iter().map(|p| p.0).collect();
        assert!(names.contains(&crate::obs::S_FRAG_PAR_SWEEP), "{names:?}");
        assert!(names.contains(&crate::obs::S_FRAG_PAR_MERGE), "{names:?}");
        let seq = rows.iter().find(|r| r.threads == 1).unwrap();
        let seqnames: Vec<&str> = seq.phases.iter().map(|p| p.0).collect();
        assert!(
            !seqnames.contains(&crate::obs::S_FRAG_PAR_SWEEP),
            "the sequential cell must not record frag-par spans: {seqnames:?}"
        );
        crate::obs::reset();
    }

    #[test]
    fn count_wins_counts() {
        let rows = vec![
            Row { dataset: "A", engine: "x", n: 1, mean_rate: 2.0, stddev_rate: 0.0, mean_secs: 1.0 },
            Row { dataset: "A", engine: "y", n: 1, mean_rate: 1.0, stddev_rate: 0.0, mean_secs: 1.0 },
            Row { dataset: "B", engine: "y", n: 1, mean_rate: 5.0, stddev_rate: 0.0, mean_secs: 1.0 },
            Row { dataset: "B", engine: "x", n: 1, mean_rate: 1.0, stddev_rate: 0.0, mean_secs: 1.0 },
        ];
        let wins = count_wins(&rows);
        assert_eq!(wins, vec![("x", 1), ("y", 1)]);
    }
}
