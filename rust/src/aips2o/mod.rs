//! AIPS²o — Augmented In-place Parallel SampleSort (engine E4): **the
//! paper's contribution** (Section 4).
//!
//! AIPS²o is IPS⁴o with Algorithm 5 deciding, per recursive call, between
//! the learned classifier (monotonic RMI, B = 1024) and the branchless
//! decision tree (B = 256, equality buckets). Everything else is inherited
//! from the shared framework:
//!
//! * in-place block partitioning + parallelization ([`crate::sample_sort`]),
//! * duplicate handling via the tree's equality buckets,
//! * SkaSort below 4096 keys ("Model-based counting sort is not used as
//!   the algorithm never forwards the RMI between recursive calls.
//!   Instead, SkaSort is used for the base case" — Section 4),
//! * the monotonic RMI means no insertion-sort repair pass is needed.

pub mod strategy;

use crate::classifier::Classifier;
use crate::key::SortKey;
use crate::radix_sort::ska_sort::ska_sort;
use crate::sample_sort::partition::partition;
use crate::scheduler::run_task_pool;
use crate::util::rng::Xoshiro256pp;
use crate::util::timer::{phase_scope, Phase};

pub use strategy::{build_partition_model, Strategy, StrategyConfig};

/// Tuning knobs of AIPS²o.
#[derive(Debug, Clone, Copy)]
pub struct Aips2oConfig {
    /// Algorithm 5's strategy-selection thresholds.
    pub strategy: StrategyConfig,
    /// Paper: SkaSort below 4096 keys.
    pub base_case: usize,
    /// Keys per buffer block.
    pub block: usize,
    /// Recursion guard (heapsort fallback).
    pub max_depth: usize,
}

impl Default for Aips2oConfig {
    fn default() -> Self {
        Aips2oConfig {
            strategy: StrategyConfig::default(),
            base_case: 4096,
            block: 128,
            max_depth: 12,
        }
    }
}

/// Sequential AIPS²o (paper name: AI1S²o).
pub fn sort_seq<K: SortKey>(data: &mut [K]) {
    sort_seq_cfg(data, &Aips2oConfig::default());
}

/// Sequential AIPS²o with explicit configuration.
pub fn sort_seq_cfg<K: SortKey>(data: &mut [K], cfg: &Aips2oConfig) {
    let mut rng = Xoshiro256pp::new(0xA1B5_0001 ^ data.len() as u64);
    sort_rec(data, cfg, cfg.max_depth, &mut rng, 1);
}

/// Parallel AIPS²o — the paper's headline configuration.
pub fn sort_par<K: SortKey>(data: &mut [K], threads: usize) {
    sort_par_cfg(data, threads, &Aips2oConfig::default());
}

/// Parallel AIPS²o with explicit configuration.
pub fn sort_par_cfg<K: SortKey>(data: &mut [K], threads: usize, cfg: &Aips2oConfig) {
    let threads = threads.max(1);
    let n = data.len();
    if threads == 1 || n <= cfg.base_case.max(4 * cfg.block * threads) {
        return sort_seq_cfg(data, cfg);
    }
    let mut rng = Xoshiro256pp::new(0xA1B5_0002 ^ n as u64);
    let Some(model) = build_partition_model(data, &cfg.strategy, &mut rng) else {
        return; // constant input
    };
    // Top level: cooperative partition with all threads.
    let result = partition(data, &model, cfg.block, threads);

    let base = data.as_mut_ptr() as usize;
    let cfg = *cfg;
    let mut tasks: Vec<(usize, usize, usize)> = Vec::new();
    for b in 0..model.num_buckets() {
        let (lo, hi) = (result.boundaries[b], result.boundaries[b + 1]);
        if hi - lo > 1 && !model.is_equality_bucket(b) {
            tasks.push((lo, hi - lo, cfg.max_depth - 1));
        }
    }
    run_task_pool(threads, tasks, move |(off, len, depth), spawner| {
        // SAFETY: partition boundaries produce disjoint ranges.
        let sub = unsafe { std::slice::from_raw_parts_mut((base as *mut K).add(off), len) };
        if len <= cfg.base_case {
            let _g = phase_scope(Phase::BaseCase);
            ska_sort(sub);
            return;
        }
        if depth == 0 {
            let _g = phase_scope(Phase::BaseCase);
            crate::sample_sort::base_case::heapsort(sub);
            return;
        }
        let mut rng = Xoshiro256pp::stream(0xA1B5_0003, off as u64);
        let Some(model) = build_partition_model(sub, &cfg.strategy, &mut rng) else {
            return;
        };
        let res = partition(sub, &model, cfg.block, 1);
        for b in 0..model.num_buckets() {
            let (lo, hi) = (res.boundaries[b], res.boundaries[b + 1]);
            if hi - lo > 1 && !model.is_equality_bucket(b) {
                spawner.spawn((off + lo, hi - lo, depth - 1));
            }
        }
    });
}

fn sort_rec<K: SortKey>(
    data: &mut [K],
    cfg: &Aips2oConfig,
    depth: usize,
    rng: &mut Xoshiro256pp,
    threads: usize,
) {
    let n = data.len();
    if n <= cfg.base_case {
        let _g = phase_scope(Phase::BaseCase);
        ska_sort(data);
        return;
    }
    if depth == 0 {
        let _g = phase_scope(Phase::BaseCase);
        crate::sample_sort::base_case::heapsort(data);
        return;
    }
    let Some(model) = build_partition_model(data, &cfg.strategy, rng) else {
        return;
    };
    let result = partition(data, &model, cfg.block, threads);
    for b in 0..model.num_buckets() {
        let (lo, hi) = (result.boundaries[b], result.boundaries[b + 1]);
        if hi - lo > 1 && !model.is_equality_bucket(b) {
            sort_rec(&mut data[lo..hi], cfg, depth - 1, rng, 1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::is_sorted;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn seq_sorts_smooth_distributions() {
        for n in [0usize, 1, 4096, 4097, 50_000, 250_000] {
            let mut rng = Xoshiro256pp::new(n as u64 + 11);
            let mut v: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1e6)).collect();
            sort_seq(&mut v);
            assert!(is_sorted(&v), "n={n}");
        }
    }

    #[test]
    fn par_sorts_and_matches() {
        for (n, t) in [(100_000usize, 2usize), (300_000, 4), (299_999, 8)] {
            let mut rng = Xoshiro256pp::new(n as u64);
            let mut v: Vec<f64> = (0..n).map(|_| rng.lognormal(0.0, 0.5)).collect();
            let mut want = v.clone();
            want.sort_unstable_by(f64::total_cmp);
            sort_par(&mut v, t);
            assert_eq!(v, want, "n={n} t={t}");
        }
    }

    #[test]
    fn duplicate_adversaries_route_to_tree() {
        let n = 200_000;
        // RootDups — the LearnedSort adversary AIPS2o must handle
        let m = (n as f64).sqrt() as u64;
        let mut v: Vec<f64> = (0..n as u64).map(|i| (i % m) as f64).collect();
        let mut want = v.clone();
        want.sort_unstable_by(f64::total_cmp);
        sort_par(&mut v, 4);
        assert_eq!(v, want);
        // near-constant
        let mut rng = Xoshiro256pp::new(21);
        let mut v: Vec<u64> = (0..n).map(|_| rng.next_below(3)).collect();
        let mut want = v.clone();
        want.sort_unstable();
        sort_par(&mut v, 4);
        assert_eq!(v, want);
    }

    #[test]
    fn u64_heavy_tail() {
        let mut rng = Xoshiro256pp::new(23);
        let mut v: Vec<u64> = (0..150_000)
            .map(|_| (rng.lognormal(20.0, 3.0)) as u64)
            .collect();
        let mut want = v.clone();
        want.sort_unstable();
        sort_par(&mut v, 4);
        assert_eq!(v, want);
    }

    #[test]
    fn constant_input() {
        let mut v = vec![1.25f64; 200_000];
        sort_par(&mut v, 4);
        assert!(is_sorted(&v));
    }

    #[test]
    fn sorted_and_reversed() {
        let mut v: Vec<f64> = (0..200_000).map(|i| i as f64).collect();
        sort_par(&mut v, 4);
        assert!(is_sorted(&v));
        let mut v: Vec<f64> = (0..200_000).rev().map(|i| i as f64).collect();
        sort_par(&mut v, 4);
        assert!(is_sorted(&v));
    }
}
