//! Paper Algorithm 5: `BuildPartitionModel` — AIPS²o's strategy selection.
//!
//! Draw a small sample; if the (sub)problem is large enough and the sample
//! is not duplicate-heavy, draw a *larger* sample ("the RMI benefits from
//! larger samples") and train the monotonic RMI with B = 1024 buckets;
//! otherwise build IPS⁴o's branchless decision tree with B = 256 and its
//! equality buckets — which is how AIPS²o "avoids the common adversarial
//! case for LearnedSort" (duplicates).

use crate::classifier::decision_tree::DecisionTree;
use crate::classifier::rmi_classifier::RmiClassifier;
use crate::classifier::Classifier;
use crate::key::SortKey;
use crate::rmi::model::{sample_f64, Rmi, RmiConfig};
use crate::util::rng::Xoshiro256pp;
use crate::util::timer::{phase_scope, Phase};

/// Algorithm 5's thresholds and sample sizes.
#[derive(Debug, Clone, Copy)]
pub struct StrategyConfig {
    /// Paper: "We default to the decision tree ... if the input size is
    /// smaller than N = 10^5".
    pub min_rmi_input: usize,
    /// Paper: "... or if there are more than 10% of duplicates in the
    /// first sample".
    pub max_dup_fraction: f64,
    /// Paper: B = 1024 buckets for the RMI.
    pub rmi_buckets: usize,
    /// Second-level models in the RMI.
    pub rmi_leaves: usize,
    /// Paper: decision tree with B = 256.
    pub tree_buckets: usize,
    /// Small first sample (duplicate probe + tree splitters).
    pub probe_sample: usize,
    /// Larger RMI training sample as a fraction of n.
    pub rmi_sample_frac: f64,
    /// Cap on the RMI training sample.
    pub rmi_sample_max: usize,
}

impl Default for StrategyConfig {
    fn default() -> Self {
        StrategyConfig {
            min_rmi_input: 100_000,
            max_dup_fraction: 0.10,
            rmi_buckets: 1024,
            rmi_leaves: 1024,
            tree_buckets: 256,
            probe_sample: 2048,
            rmi_sample_frac: 0.01,
            rmi_sample_max: 1 << 16,
        }
    }
}

/// The chosen partitioning model: either the learned classifier or the
/// comparison-based splitter tree.
pub enum Strategy<K: SortKey> {
    /// The learned classifier (monotonic RMI, B = 1024).
    Rmi(RmiClassifier),
    /// IPS⁴o's branchless splitter tree (B = 256, equality buckets).
    Tree(DecisionTree<K>),
}

impl<K: SortKey> Strategy<K> {
    /// True when Algorithm 5 chose the RMI.
    pub fn is_learned(&self) -> bool {
        matches!(self, Strategy::Rmi(_))
    }
}

impl<K: SortKey> Classifier<K> for Strategy<K> {
    fn num_buckets(&self) -> usize {
        match self {
            Strategy::Rmi(c) => Classifier::<K>::num_buckets(c),
            Strategy::Tree(c) => c.num_buckets(),
        }
    }

    #[inline(always)]
    fn classify(&self, key: K) -> usize {
        match self {
            Strategy::Rmi(c) => Classifier::<K>::classify(c, key),
            Strategy::Tree(c) => c.classify(key),
        }
    }

    fn is_equality_bucket(&self, b: usize) -> bool {
        match self {
            Strategy::Rmi(c) => Classifier::<K>::is_equality_bucket(c, b),
            Strategy::Tree(c) => c.is_equality_bucket(b),
        }
    }

    fn classify_batch(&self, keys: &[K], out: &mut [u32]) {
        // The RMI arm dispatches into the shared 8-wide branchless batch
        // kernel (`Rmi::predict_batch`) — the same prediction loop the
        // LearnedSort 2.0 fragmentation sweep runs, so both learned paths
        // pipeline their leaf-table loads identically.
        match self {
            Strategy::Rmi(c) => c.classify_batch(keys, out),
            Strategy::Tree(c) => c.classify_batch(keys, out),
        }
    }
}

/// Duplicate fraction of a sorted sample: 1 - distinct/len.
pub fn duplicate_fraction<K: SortKey>(sorted_sample: &[K]) -> f64 {
    if sorted_sample.len() < 2 {
        return 0.0;
    }
    let distinct = 1 + sorted_sample
        .windows(2)
        .filter(|w| !w[0].key_eq(w[1]))
        .count();
    1.0 - distinct as f64 / sorted_sample.len() as f64
}

/// Algorithm 5. Returns `None` when the input is constant (already
/// sorted — nothing to partition).
pub fn build_partition_model<K: SortKey>(
    data: &[K],
    cfg: &StrategyConfig,
    rng: &mut Xoshiro256pp,
) -> Option<Strategy<K>> {
    let _g = phase_scope(Phase::Sampling);
    let n = data.len();
    // S <- Sample(A, l, r); Sort(S) — probe scales down with n so deep
    // recursion levels don't pay a fixed 2048-key sample (perf log).
    let probe_n = cfg.probe_sample.min((n / 16).max(256)).min(n);
    let mut probe: Vec<K> = (0..probe_n)
        .map(|_| data[rng.next_below(n as u64) as usize])
        .collect();
    probe.sort_unstable_by(|a, b| a.to_bits_ordered().cmp(&b.to_bits_ordered()));

    if probe.first().map(|k| k.to_bits_ordered()) == probe.last().map(|k| k.to_bits_ordered()) {
        let v = probe.first()?.to_bits_ordered();
        if data.iter().all(|k| k.to_bits_ordered() == v) {
            return None;
        }
    }

    let input_is_large = n >= cfg.min_rmi_input;
    let too_many_duplicates = duplicate_fraction(&probe) > cfg.max_dup_fraction;

    if input_is_large && !too_many_duplicates {
        // R <- LargerSample(A, l, r); Sort(R); rmi <- BuildRMI(R)
        let _t = phase_scope(Phase::ModelTrain);
        let ssz = ((n as f64 * cfg.rmi_sample_frac) as usize)
            .clamp(cfg.probe_sample, cfg.rmi_sample_max)
            .min(n);
        let mut sample = Vec::new();
        sample_f64(data, ssz, rng, &mut sample);
        sample.sort_unstable_by(f64::total_cmp);
        let rmi = Rmi::train(
            &sample,
            RmiConfig {
                n_leaves: cfg.rmi_leaves,
            },
        );
        Some(Strategy::Rmi(RmiClassifier::new(rmi, cfg.rmi_buckets)))
    } else {
        // tree <- BuildBranchlessDecisionTree(S); fan-out shrinks on small
        // sub-problems so buckets land near the SkaSort base-case size
        let k = cfg
            .tree_buckets
            .min((n / 4096).max(2).next_power_of_two())
            .max(2);
        Some(Strategy::Tree(DecisionTree::from_sorted_sample(&probe, k)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256pp {
        Xoshiro256pp::new(0xA1B5)
    }

    #[test]
    fn large_smooth_input_gets_rmi() {
        let mut r = rng();
        let data: Vec<f64> = (0..200_000).map(|_| r.uniform(0.0, 1e6)).collect();
        let s = build_partition_model(&data, &StrategyConfig::default(), &mut r).unwrap();
        assert!(s.is_learned());
        assert_eq!(Classifier::<f64>::num_buckets(&s), 1024);
    }

    #[test]
    fn small_input_gets_tree() {
        let mut r = rng();
        let data: Vec<f64> = (0..50_000).map(|_| r.uniform(0.0, 1e6)).collect();
        let s = build_partition_model(&data, &StrategyConfig::default(), &mut r).unwrap();
        assert!(!s.is_learned());
    }

    #[test]
    fn duplicate_heavy_input_gets_tree() {
        let mut r = rng();
        let data: Vec<u64> = (0..200_000).map(|_| r.next_below(10)).collect();
        let s = build_partition_model(&data, &StrategyConfig::default(), &mut r).unwrap();
        assert!(!s.is_learned(), "duplicates must route to the tree");
    }

    #[test]
    fn constant_input_returns_none() {
        let mut r = rng();
        let data = vec![9u64; 150_000];
        assert!(build_partition_model(&data, &StrategyConfig::default(), &mut r).is_none());
    }

    #[test]
    fn duplicate_fraction_measures() {
        assert_eq!(duplicate_fraction::<u64>(&[]), 0.0);
        assert_eq!(duplicate_fraction(&[1u64, 2, 3, 4]), 0.0);
        assert_eq!(duplicate_fraction(&[1u64, 1, 1, 1]), 0.75);
        assert!((duplicate_fraction(&[1u64, 1, 2, 3]) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn strategy_classify_dispatch() {
        let mut r = rng();
        let data: Vec<f64> = (0..200_000).map(|_| r.uniform(0.0, 1e6)).collect();
        let s = build_partition_model(&data, &StrategyConfig::default(), &mut r).unwrap();
        let mut out = vec![0u32; 100];
        s.classify_batch(&data[..100], &mut out);
        for (k, o) in data[..100].iter().zip(&out) {
            assert_eq!(*o as usize, s.classify(*k));
        }
    }
}
