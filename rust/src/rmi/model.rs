//! The two-layer linear RMI with the paper's monotonicity constraint.
//!
//! Model contract (shared with `python/compile/model.py`):
//!
//! * root: `(a1, b1)`; leaf index `i = clamp(floor((a1*x + b1) * B))`
//! * leaf i: `(a2, b2, lo, hi)`; `F(x) = clip(a2*x + b2, lo, hi)` and then
//!   `clip(F, 0, 1-eps)`.
//!
//! Monotonicity (paper Section 4): the root and leaf slopes are clamped
//! nonnegative and each leaf's output is clamped to the cumulative
//! empirical-CDF envelope `[lo_i, hi_i]` with `hi_i <= lo_{i+1}` — so
//! `x <= y ⇒ F(x) <= F(y)` *globally*, which lets AIPS²o partition with the
//! model and skip LearnedSort's insertion-sort repair pass.

use crate::key::SortKey;
use crate::rmi::linear::FitStats;
use crate::util::rng::Xoshiro256pp;

/// `F(x) < 1` strictly: bucket = floor(F*B) stays in range.
pub const ONE_MINUS_EPS: f64 = 1.0 - 2.2204460492503131e-16; // 1 - 2^-52

/// Training hyper-parameters of the two-layer RMI.
#[derive(Debug, Clone, Copy)]
pub struct RmiConfig {
    /// Number of second-level models B (paper: 1000 for LearnedSort,
    /// 1024 for AIPS²o).
    pub n_leaves: usize,
}

impl Default for RmiConfig {
    fn default() -> Self {
        RmiConfig { n_leaves: 1024 }
    }
}

/// One second-level linear model with its monotonic envelope.
#[derive(Debug, Clone, Copy, Default)]
pub struct Leaf {
    /// Slope (clamped nonnegative).
    pub a: f64,
    /// Intercept.
    pub b: f64,
    /// Lower envelope bound (cumulative CDF mass before this leaf).
    pub lo: f64,
    /// Upper envelope bound (cumulative CDF mass through this leaf).
    pub hi: f64,
}

/// Trained two-layer RMI.
#[derive(Debug, Clone)]
pub struct Rmi {
    /// Root slope.
    pub root_a: f64,
    /// Root intercept.
    pub root_b: f64,
    /// Second-level models, in leaf order.
    pub leaves: Vec<Leaf>,
}

impl Rmi {
    /// Train from a **sorted** sample (duplicates allowed). Mirrors
    /// `model.rmi_train` in the JAX layer: same root fit, same per-leaf
    /// sufficient statistics, same envelope.
    pub fn train(sample_sorted: &[f64], cfg: RmiConfig) -> Rmi {
        let n = sample_sorted.len();
        let n_leaves = cfg.n_leaves.max(1);
        // Root fit over (x_j, y_j = (j + 0.5)/n).
        let mut root_stats = FitStats::default();
        for (j, &x) in sample_sorted.iter().enumerate() {
            let y = (j as f64 + 0.5) / n.max(1) as f64;
            root_stats.add(x, y);
        }
        let (root_a, root_b) = root_stats.fit_monotone();

        // Per-leaf sufficient statistics (the Pallas kernel's job in L1).
        let mut stats = vec![FitStats::default(); n_leaves];
        for (j, &x) in sample_sorted.iter().enumerate() {
            let y = (j as f64 + 0.5) / n.max(1) as f64;
            let i = leaf_index(root_a, root_b, n_leaves, x);
            stats[i].add(x, y);
        }

        // Closed-form leaf fits + cumulative envelope (= ref_fit_leaves).
        let total: f64 = stats.iter().map(|s| s.cnt).sum::<f64>().max(1.0);
        let mut leaves = Vec::with_capacity(n_leaves);
        let mut cum = 0.0;
        for s in &stats {
            let (a, b) = s.fit_monotone();
            let lo = cum / total;
            cum += s.cnt;
            let hi = cum / total;
            leaves.push(Leaf { a, b, lo, hi });
        }
        Rmi {
            root_a,
            root_b,
            leaves,
        }
    }

    /// Build by drawing and sorting a random sample from `keys` (the paper's
    /// training procedure: sample, sort the sample, fit).
    pub fn train_from_keys<K: SortKey>(
        keys: &[K],
        sample_size: usize,
        cfg: RmiConfig,
        rng: &mut Xoshiro256pp,
    ) -> Rmi {
        let mut sample = Vec::new();
        sample_f64(keys, sample_size, rng, &mut sample);
        sample.sort_unstable_by(f64::total_cmp);
        Rmi::train(&sample, cfg)
    }

    /// Construct directly from raw parameter arrays (as returned by the
    /// PJRT `rmi_train` artifact: root f64[2], leaf f64[B,4] row-major).
    pub fn from_params(root: &[f64], leaf_rows: &[f64]) -> Rmi {
        assert_eq!(root.len(), 2);
        assert_eq!(leaf_rows.len() % 4, 0);
        let leaves = leaf_rows
            .chunks_exact(4)
            .map(|r| Leaf {
                a: r[0],
                b: r[1],
                lo: r[2],
                hi: r[3],
            })
            .collect();
        Rmi {
            root_a: root[0],
            root_b: root[1],
            leaves,
        }
    }

    /// Flatten to (root[2], leaf[B*4]) — the artifact parameter layout.
    pub fn to_params(&self) -> (Vec<f64>, Vec<f64>) {
        let root = vec![self.root_a, self.root_b];
        let mut leaf = Vec::with_capacity(self.leaves.len() * 4);
        for l in &self.leaves {
            leaf.extend_from_slice(&[l.a, l.b, l.lo, l.hi]);
        }
        (root, leaf)
    }

    /// Number of second-level models.
    #[inline(always)]
    pub fn n_leaves(&self) -> usize {
        self.leaves.len()
    }

    /// Predicted CDF in [0, 1). The hot-path: 2 FMAs + 2 clamps + 1 load.
    #[inline(always)]
    pub fn predict(&self, x: f64) -> f64 {
        // ±inf inputs would turn a degenerate (slope 0) leaf into NaN via
        // 0*inf; clamping to the finite range keeps F total and monotone.
        let x = x.clamp(f64::MIN, f64::MAX);
        let i = leaf_index(self.root_a, self.root_b, self.leaves.len(), x);
        // SAFETY: leaf_index clamps into 0..n_leaves.
        let l = unsafe { self.leaves.get_unchecked(i) };
        // branchless clamps (maxsd/minsd) — the hot loop must not depend
        // on data-dependent branches (perf log, EXPERIMENTS.md §Perf)
        let p = (l.a * x + l.b).max(l.lo).min(l.hi);
        p.max(0.0).min(ONE_MINUS_EPS)
    }

    /// Batched prediction: `W` independent evaluations of [`Rmi::predict`]
    /// per call. The evaluations carry no cross-lane dependencies and no
    /// data-dependent branches (the clamps compile to `maxsd`/`minsd`), so
    /// the leaf-table loads pipeline instead of serializing — the shared
    /// hot path of the LearnedSort 2.0 fragmentation sweep and AIPS²o's
    /// learned classifier (both call with `W = 8`).
    #[inline]
    pub fn predict_batch<const W: usize>(&self, xs: &[f64; W]) -> [f64; W] {
        let n_leaves = self.leaves.len();
        let mut out = [0.0f64; W];
        for (o, x) in out.iter_mut().zip(xs.iter()) {
            let x = x.clamp(f64::MIN, f64::MAX);
            let i = leaf_index(self.root_a, self.root_b, n_leaves, x);
            // SAFETY: leaf_index clamps into 0..n_leaves.
            let l = unsafe { self.leaves.get_unchecked(i) };
            let p = (l.a * x + l.b).max(l.lo).min(l.hi);
            *o = p.max(0.0).min(ONE_MINUS_EPS);
        }
        out
    }

    /// Bucket index for a `n_buckets`-way partition: floor(F(x) * n_buckets).
    #[inline(always)]
    pub fn bucket(&self, x: f64, n_buckets: usize) -> usize {
        let b = (self.predict(x) * n_buckets as f64) as usize;
        if b >= n_buckets {
            n_buckets - 1
        } else {
            b
        }
    }

    /// True iff predictions are nondecreasing over `probe` (diagnostic;
    /// the construction guarantees it, tests verify).
    pub fn is_monotone_over(&self, probe_sorted: &[f64]) -> bool {
        let mut prev = f64::NEG_INFINITY;
        for &x in probe_sorted {
            let p = self.predict(x);
            if p < prev {
                return false;
            }
            prev = p;
        }
        true
    }
}

/// Root-level leaf selection: clamp(floor((a1*x + b1) * B), 0, B-1).
#[inline(always)]
pub fn leaf_index(root_a: f64, root_b: f64, n_leaves: usize, x: f64) -> usize {
    let pos = (root_a * x + root_b) * n_leaves as f64;
    // cast saturates toward 0 for NaN/negative; clamp the top explicitly
    let i = pos as usize; // f64->usize casts are saturating in Rust
    if i >= n_leaves {
        n_leaves - 1
    } else {
        i
    }
}

/// Draw `k` keys (as f64 model embeddings) without replacement.
pub fn sample_f64<K: SortKey>(
    keys: &[K],
    k: usize,
    rng: &mut Xoshiro256pp,
    out: &mut Vec<f64>,
) {
    out.clear();
    if keys.is_empty() || k == 0 {
        return;
    }
    if k >= keys.len() {
        out.extend(keys.iter().map(|x| x.to_f64()));
        return;
    }
    // Random index draws (with replacement) — what LearnedSort does; cheap
    // and unbiased enough at 1% sampling rates.
    out.reserve(k);
    for _ in 0..k {
        let i = rng.next_below(keys.len() as u64) as usize;
        out.push(keys[i].to_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_sample(n: usize) -> Vec<f64> {
        let mut rng = Xoshiro256pp::new(1);
        let mut v: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1e6)).collect();
        v.sort_unstable_by(f64::total_cmp);
        v
    }

    #[test]
    fn uniform_cdf_accurate() {
        let sample = uniform_sample(8192);
        let rmi = Rmi::train(&sample, RmiConfig { n_leaves: 256 });
        // mean |F(x) - x/1e6| small on uniform
        let mut err = 0.0;
        let mut cnt = 0;
        for i in 0..1000 {
            let x = i as f64 * 1e3;
            err += (rmi.predict(x) - x / 1e6).abs();
            cnt += 1;
        }
        assert!(err / (cnt as f64) < 0.01, "err={}", err / cnt as f64);
    }

    #[test]
    fn monotone_guarantee() {
        for dist in 0..3 {
            let mut rng = Xoshiro256pp::new(100 + dist);
            let mut sample: Vec<f64> = (0..4096)
                .map(|_| match dist {
                    0 => rng.lognormal(0.0, 0.5),
                    1 => rng.normal(),
                    _ => (rng.next_below(50)) as f64, // heavy duplicates
                })
                .collect();
            sample.sort_unstable_by(f64::total_cmp);
            let rmi = Rmi::train(&sample, RmiConfig { n_leaves: 128 });
            let mut probe: Vec<f64> = (0..8192)
                .map(|_| match dist {
                    0 => rng.lognormal(0.0, 0.5),
                    1 => rng.normal(),
                    _ => (rng.next_below(50)) as f64,
                })
                .collect();
            probe.sort_unstable_by(f64::total_cmp);
            assert!(rmi.is_monotone_over(&probe), "dist {dist} not monotone");
        }
    }

    #[test]
    fn predictions_in_range() {
        let sample = uniform_sample(1024);
        let rmi = Rmi::train(&sample, RmiConfig { n_leaves: 64 });
        for x in [-1e300, -5.0, 0.0, 5e5, 2e6, 1e300, f64::INFINITY] {
            let p = rmi.predict(x);
            assert!((0.0..1.0).contains(&p), "predict({x}) = {p}");
        }
        for x in [-1e9, 0.0, 1e9] {
            let b = rmi.bucket(x, 1000);
            assert!(b < 1000);
        }
    }

    #[test]
    fn predict_batch_matches_scalar() {
        let sample = uniform_sample(4096);
        let rmi = Rmi::train(&sample, RmiConfig { n_leaves: 64 });
        let xs = [-1e9, 0.0, 1.0, 2.5e5, 5e5, 7.5e5, 1e6, 2e9];
        let ps = rmi.predict_batch(&xs);
        for (x, p) in xs.iter().zip(ps.iter()) {
            assert_eq!(*p, rmi.predict(*x));
        }
        // infinities clamp the same way in both paths
        let edge = [f64::NEG_INFINITY, f64::INFINITY];
        let pe = rmi.predict_batch(&edge);
        assert_eq!(pe[0], rmi.predict(f64::NEG_INFINITY));
        assert_eq!(pe[1], rmi.predict(f64::INFINITY));
    }

    #[test]
    fn constant_input_degenerates_gracefully() {
        let sample = vec![7.0; 512];
        let rmi = Rmi::train(&sample, RmiConfig { n_leaves: 16 });
        let p = rmi.predict(7.0);
        assert!((0.0..1.0).contains(&p));
        assert!(rmi.predict(6.0) <= rmi.predict(8.0));
    }

    #[test]
    fn params_roundtrip() {
        let sample = uniform_sample(2048);
        let rmi = Rmi::train(&sample, RmiConfig { n_leaves: 32 });
        let (root, leaf) = rmi.to_params();
        let back = Rmi::from_params(&root, &leaf);
        for x in [0.0, 1e5, 9e5] {
            assert_eq!(rmi.predict(x), back.predict(x));
        }
    }

    #[test]
    fn envelope_tiles_unit_interval() {
        let sample = uniform_sample(4096);
        let rmi = Rmi::train(&sample, RmiConfig { n_leaves: 64 });
        for w in rmi.leaves.windows(2) {
            assert!(w[0].hi <= w[1].lo + 1e-15);
            assert!(w[0].lo <= w[0].hi + 1e-15);
        }
        assert!(rmi.leaves[0].lo.abs() < 1e-15);
        assert!((rmi.leaves.last().unwrap().hi - 1.0).abs() < 1e-12);
    }

    #[test]
    fn leaf_index_clamps() {
        assert_eq!(leaf_index(1.0, 0.0, 10, -5.0), 0);
        assert_eq!(leaf_index(1.0, 0.0, 10, 50.0), 9);
        assert_eq!(leaf_index(1.0, 0.0, 10, 0.55), 5);
        assert_eq!(leaf_index(f64::NAN, 0.0, 10, 1.0), 0); // NaN -> 0 cast
    }

    #[test]
    fn train_from_keys_u64() {
        let mut rng = Xoshiro256pp::new(3);
        let keys: Vec<u64> = (0..10_000).map(|_| rng.next_below(1 << 40)).collect();
        let rmi = Rmi::train_from_keys(&keys, 512, RmiConfig { n_leaves: 64 }, &mut rng);
        assert_eq!(rmi.n_leaves(), 64);
        let p_small = rmi.predict(0.0);
        let p_big = rmi.predict((1u64 << 40) as f64);
        assert!(p_small <= p_big);
    }

    #[test]
    fn empty_sample_is_safe() {
        let rmi = Rmi::train(&[], RmiConfig { n_leaves: 8 });
        let p = rmi.predict(1.0);
        assert!((0.0..1.0).contains(&p));
    }
}
