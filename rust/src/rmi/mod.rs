//! Native RMI — the two-layer linear Recursive Model Index (substrate S7).
//!
//! This is the CDF model at the heart of LearnedSort and AIPS²o, mirroring
//! `python/compile/model.py` op-for-op (the same closed-form least-squares
//! fits, the same monotonic envelope). The JAX/Pallas implementation is the
//! AOT-compiled reference executed through PJRT ([`crate::runtime`]); this
//! native mirror is the in-loop hot path — see DESIGN.md §1 for why both
//! exist, and `rust/tests/pjrt_parity.rs` for the cross-validation.
//!
//! ```
//! use aipso::rmi::{Rmi, RmiConfig};
//!
//! // train on a sorted sample; F is a monotone CDF estimate in [0, 1)
//! let sample: Vec<f64> = (0..4096).map(|i| i as f64).collect();
//! let rmi = Rmi::train(&sample, RmiConfig { n_leaves: 64 });
//! let (lo, mid, hi) = (rmi.predict(0.0), rmi.predict(2048.0), rmi.predict(4095.0));
//! assert!(lo <= mid && mid <= hi);
//! assert!((mid - 0.5).abs() < 0.05, "midpoint CDF ~ 0.5, got {mid}");
//!
//! // the external sorter's sharded merge inverts it back into keys
//! let median: f64 = aipso::rmi::quality::quantile_key(&rmi, 0.5);
//! assert!((median - 2048.0).abs() < 200.0);
//! ```

pub mod linear;
pub mod model;
pub mod quality;

pub use model::{Rmi, RmiConfig};
