//! Native RMI — the two-layer linear Recursive Model Index (substrate S7).
//!
//! This is the CDF model at the heart of LearnedSort and AIPS²o, mirroring
//! `python/compile/model.py` op-for-op (the same closed-form least-squares
//! fits, the same monotonic envelope). The JAX/Pallas implementation is the
//! AOT-compiled reference executed through PJRT ([`crate::runtime`]); this
//! native mirror is the in-loop hot path — see DESIGN.md §1 for why both
//! exist, and `rust/tests/pjrt_parity.rs` for the cross-validation.

pub mod linear;
pub mod model;
pub mod quality;

pub use model::{Rmi, RmiConfig};
