//! Pivot extraction and quality measurement — paper Algorithm 4 + Table 2.
//!
//! Algorithm 4 ("LearnedPivotsForSampleSort") materializes the pivots that
//! LearnedSort uses *implicitly*: for each percentile (i+1)/B, the largest
//! element of A whose predicted CDF is below it. Table 2 scores pivot sets
//! by the distance between the pivots' true CDF and the perfect splitters:
//! `sum_i |P(A <= p_i) - (i+1)/B|`.

use crate::key::SortKey;
use crate::rmi::model::Rmi;
use crate::util::rng::Xoshiro256pp;

/// Paper Algorithm 4: extract the B-1 implicit pivots of LearnedSort.
///
/// Single O(N + B) pass instead of the paper's O(N·B) pseudocode loop: for
/// each element we bump the per-percentile maximum of its predicted-CDF
/// cell, then prefix-max across cells (valid because "largest element with
/// F(x) <= (i+1)/B" is monotone in i).
pub fn learned_pivots<K: SortKey>(rmi: &Rmi, keys: &[K], n_buckets: usize) -> Vec<Option<K>> {
    assert!(n_buckets >= 2);
    let mut cell_max: Vec<Option<K>> = vec![None; n_buckets];
    for &k in keys {
        let f = rmi.predict(k.to_f64());
        let cell = ((f * n_buckets as f64) as usize).min(n_buckets - 1);
        cell_max[cell] = Some(match cell_max[cell] {
            None => k,
            Some(m) => m.key_max(k),
        });
    }
    // pivot_i = max over cells <= i (largest element with F below the
    // (i+1)/B percentile); B-1 pivots for B buckets.
    let mut out = Vec::with_capacity(n_buckets - 1);
    let mut running: Option<K> = None;
    for cell in cell_max.iter().take(n_buckets - 1) {
        running = match (running, *cell) {
            (None, c) => c,
            (Some(r), None) => Some(r),
            (Some(r), Some(c)) => Some(r.key_max(c)),
        };
        out.push(running);
    }
    out
}

/// Random pivots the way IPS⁴o selects splitters: draw `oversample *
/// (n_pivots+1)` random elements, sort them, take every `oversample`-th.
pub fn random_pivots<K: SortKey>(
    keys: &[K],
    n_pivots: usize,
    oversample: usize,
    rng: &mut Xoshiro256pp,
) -> Vec<K> {
    assert!(!keys.is_empty());
    let m = oversample.max(1) * (n_pivots + 1);
    let mut sample: Vec<K> = (0..m)
        .map(|_| keys[rng.next_below(keys.len() as u64) as usize])
        .collect();
    sample.sort_unstable_by(|a, b| a.to_bits_ordered().cmp(&b.to_bits_ordered()));
    (1..=n_pivots)
        .map(|i| sample[i * oversample.max(1) - 1])
        .collect()
}

/// True CDF of `p` in `sorted`: (# elements <= p) / N, via binary search.
pub fn true_cdf<K: SortKey>(sorted: &[K], p: K) -> f64 {
    let pb = p.to_bits_ordered();
    let count = sorted.partition_point(|x| x.to_bits_ordered() <= pb);
    count as f64 / sorted.len().max(1) as f64
}

/// Table 2's quality metric: `sum_i |P(A <= p_i) - (i+1)/B|`.
/// Lower is better; 0 means perfect equidistant splitters.
pub fn pivot_quality<K: SortKey>(sorted: &[K], pivots: &[Option<K>]) -> f64 {
    let b = pivots.len() + 1;
    pivots
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let target = (i + 1) as f64 / b as f64;
            match p {
                Some(p) => (true_cdf(sorted, *p) - target).abs(),
                // a missing pivot (empty prediction cell) acts like the
                // smallest element: true CDF contribution 0
                None => target,
            }
        })
        .sum()
}

/// Drift score of a trained model against a fresh **sorted** probe: the
/// mean |F(x) − empirical CDF(x)| over the probe. 0 means the model still
/// describes the data perfectly; the external sorter's run generation
/// falls back to IPS⁴o when this exceeds its drift threshold.
pub fn model_drift(rmi: &Rmi, probe_sorted: &[f64]) -> f64 {
    let m = probe_sorted.len();
    if m == 0 {
        return 0.0;
    }
    let mut err = 0.0;
    for (j, &x) in probe_sorted.iter().enumerate() {
        let emp = (j as f64 + 0.5) / m as f64;
        err += (rmi.predict(x) - emp).abs();
    }
    err / m as f64
}

/// Invert the model: the smallest key of domain `K` whose predicted CDF
/// reaches `q`, found by binary search over the key's *ordered-bits* space
/// (valid because the monotonic envelope makes `F` nondecreasing over the
/// whole domain). The parallel external merge uses this to cut the global
/// key range into equal-probability shards — and because shard correctness
/// only needs *consistent* cuts, a model that has drifted merely skews the
/// shard sizes (which the caller guards against), never the output.
pub fn quantile_key<K: SortKey>(rmi: &Rmi, q: f64) -> K {
    quantile_key_weighted(&[(rmi, 1.0)], q)
}

/// Invert a weighted *mixture* of monotone models: the smallest key of
/// domain `K` whose weighted-mean predicted CDF reaches `q` (weights need
/// not be normalized; non-positive weights are ignored). With one model
/// this is exactly [`quantile_key`].
///
/// The external sorter's retrain-on-drift policy produces one model per
/// regime *epoch*; no single epoch model describes the whole stream after
/// a regime change, but the keys-per-epoch weighted mixture is precisely
/// the stream's estimated global CDF — each `F_e` models its regime and
/// the weights are the regimes' relative volumes. Cutting shards at the
/// mixture's quantiles therefore keeps the parallel merge balanced where
/// cuts from any one epoch's model would collapse the other regimes into
/// a single shard and trip the skew guard. Like `quantile_key`, the
/// mixture is nondecreasing (a convex combination of monotone CDFs), so
/// the same ordered-bits binary search applies.
pub fn quantile_key_weighted<K: SortKey>(models: &[(&Rmi, f64)], q: f64) -> K {
    quantile_key_mixture(models, None, q)
}

/// [`quantile_key_weighted`] extended with an optional **empirical-CDF
/// component**: a sorted sample of ordered key bits plus its weight.
///
/// Fallback chunks (drifted, duplicate-vetoed, or sorted before any model
/// existed) carry no epoch model, so a mostly-fallback stream used to cut
/// its merge shards from whatever stale models remained. Feeding a sample
/// of the fallback keys in as one more mixture component restores their
/// mass: the component's CDF is the sample's step function
/// `|{s ≤ x}| / |sample|`, weighted by the fallback key count, so the
/// mixture stays the stream's estimated global CDF even when most of the
/// stream never went through a model. A step function is nondecreasing,
/// so the ordered-bits binary search still applies; an empty sample (or a
/// non-positive weight) contributes nothing.
pub fn quantile_key_mixture<K: SortKey>(
    models: &[(&Rmi, f64)],
    empirical: Option<(&[u64], f64)>,
    q: f64,
) -> K {
    let emp = match empirical {
        Some((bits, w)) if !bits.is_empty() && w > 0.0 => Some((bits, w)),
        _ => None,
    };
    let total: f64 = models.iter().map(|(_, w)| w.max(0.0)).sum::<f64>()
        + emp.map_or(0.0, |(_, w)| w);
    let predict = |bits: u64| -> f64 {
        let x = K::from_bits_ordered(bits).to_f64();
        let emp_cdf = |sample: &[u64]| {
            sample.partition_point(|&s| s <= bits) as f64 / sample.len() as f64
        };
        if total > 0.0 {
            let mut sum: f64 = models.iter().map(|(m, w)| w.max(0.0) * m.predict(x)).sum();
            if let Some((sample, w)) = emp {
                sum += w * emp_cdf(sample);
            }
            sum / total
        } else {
            // degenerate weights: fall back to an unweighted mean so the
            // search still terminates on a valid key
            let n = (models.len() + emp.iter().len()).max(1) as f64;
            let mut sum: f64 = models.iter().map(|(m, _)| m.predict(x)).sum();
            if let Some((sample, _)) = emp {
                sum += emp_cdf(sample);
            }
            sum / n
        }
    };
    // Clamp the search to the domain's ordered range: past
    // `max_ordered_bits` the bits→key mapping of 32-bit domains truncates
    // and the predicate stops being monotone.
    let (mut lo, mut hi) = (0u64, K::max_ordered_bits());
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if predict(mid) >= q {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    K::from_bits_ordered(lo)
}

/// Convenience for pivot sets without gaps.
pub fn pivot_quality_exact<K: SortKey>(sorted: &[K], pivots: &[K]) -> f64 {
    let wrapped: Vec<Option<K>> = pivots.iter().map(|&p| Some(p)).collect();
    pivot_quality(sorted, &wrapped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmi::model::RmiConfig;

    #[test]
    fn perfect_pivots_score_zero() {
        // sorted 0..1000, perfect splitters for B=4 are 249, 499, 749
        let sorted: Vec<u64> = (0..1000).collect();
        let pivots = vec![249u64, 499, 749];
        let q = pivot_quality_exact(&sorted, &pivots);
        assert!(q < 1e-9, "q={q}");
    }

    #[test]
    fn bad_pivots_score_high() {
        let sorted: Vec<u64> = (0..1000).collect();
        // all pivots at the minimum — worst case
        let pivots = vec![0u64, 0, 0];
        let q = pivot_quality_exact(&sorted, &pivots);
        // |0.001-0.25| + |0.001-0.5| + |0.001-0.75| ≈ 1.497
        assert!(q > 1.4, "q={q}");
    }

    #[test]
    fn true_cdf_counts_leq() {
        let sorted = vec![1u64, 2, 2, 3];
        assert_eq!(true_cdf(&sorted, 2u64), 0.75);
        assert_eq!(true_cdf(&sorted, 0u64), 0.0);
        assert_eq!(true_cdf(&sorted, 3u64), 1.0);
    }

    #[test]
    fn learned_pivots_beat_worst_case_on_uniform() {
        let mut rng = Xoshiro256pp::new(5);
        let keys: Vec<f64> = (0..100_000).map(|_| rng.uniform(0.0, 1e6)).collect();
        let rmi = Rmi::train_from_keys(&keys, 2048, RmiConfig { n_leaves: 256 }, &mut rng);
        let pivots = learned_pivots(&rmi, &keys, 256);
        assert_eq!(pivots.len(), 255);
        let mut sorted = keys.clone();
        sorted.sort_unstable_by(f64::total_cmp);
        let q_learned = pivot_quality(&sorted, &pivots);
        let rp = random_pivots(&keys, 255, 2, &mut rng);
        let q_random = pivot_quality_exact(&sorted, &rp);
        // Table 2's headline: learned pivots clearly better on uniform
        assert!(
            q_learned < q_random,
            "learned {q_learned} !< random {q_random}"
        );
        assert!(q_learned < 2.0);
    }

    #[test]
    fn random_pivots_are_sorted_and_in_range() {
        let mut rng = Xoshiro256pp::new(7);
        let keys: Vec<u64> = (0..10_000).map(|_| rng.next_below(1 << 32)).collect();
        let p = random_pivots(&keys, 15, 3, &mut rng);
        assert_eq!(p.len(), 15);
        for w in p.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn model_drift_low_in_distribution_high_after_shift() {
        let mut rng = Xoshiro256pp::new(0xD21F);
        let mut sample: Vec<f64> = (0..8192).map(|_| rng.uniform(0.0, 1e6)).collect();
        sample.sort_unstable_by(f64::total_cmp);
        let rmi = Rmi::train(&sample, RmiConfig { n_leaves: 256 });
        let mut probe: Vec<f64> = (0..2048).map(|_| rng.uniform(0.0, 1e6)).collect();
        probe.sort_unstable_by(f64::total_cmp);
        let in_dist = model_drift(&rmi, &probe);
        assert!(in_dist < 0.02, "in-distribution drift {in_dist}");
        // shifted regime: the model predicts ~1.0 everywhere
        let mut shifted: Vec<f64> = (0..2048).map(|_| rng.uniform(5e6, 6e6)).collect();
        shifted.sort_unstable_by(f64::total_cmp);
        let out_dist = model_drift(&rmi, &shifted);
        assert!(out_dist > 0.2, "shifted drift {out_dist}");
        assert_eq!(model_drift(&rmi, &[]), 0.0);
    }

    #[test]
    fn quantile_key_inverts_uniform_cdf() {
        let mut rng = Xoshiro256pp::new(0xA11CE);
        let mut sample: Vec<f64> = (0..16_384).map(|_| rng.uniform(0.0, 1e6)).collect();
        sample.sort_unstable_by(f64::total_cmp);
        let rmi = Rmi::train(&sample, RmiConfig { n_leaves: 256 });
        // on U(0, 1e6) the q-quantile key is ~q * 1e6
        for q in [0.1, 0.25, 0.5, 0.75, 0.9] {
            let k: f64 = quantile_key(&rmi, q);
            assert!(
                (k - q * 1e6).abs() < 5e4,
                "q={q}: key {k} far from {}",
                q * 1e6
            );
            // the returned key is the *smallest* reaching q
            assert!(rmi.predict(k) >= q);
        }
        // quantile keys are nondecreasing in q (monotone model)
        let a: f64 = quantile_key(&rmi, 0.2);
        let b: f64 = quantile_key(&rmi, 0.8);
        assert!(a.to_bits_ordered() <= b.to_bits_ordered());
        // u64 domain: degenerate extremes stay in range
        let lo: u64 = quantile_key(&rmi, 0.0);
        let _ = lo; // q=0 resolves to the domain minimum, still a valid key
    }

    #[test]
    fn weighted_quantiles_invert_the_mixture() {
        let mut rng = Xoshiro256pp::new(0x3140);
        let train = |lo: f64, hi: f64, rng: &mut Xoshiro256pp| {
            let mut s: Vec<f64> = (0..8192).map(|_| rng.uniform(lo, hi)).collect();
            s.sort_unstable_by(f64::total_cmp);
            Rmi::train(&s, RmiConfig { n_leaves: 128 })
        };
        let low = train(0.0, 1e5, &mut rng); // regime A
        let high = train(9e5, 1e6, &mut rng); // regime B
        // equal weights: the mixture's median separates the regimes and
        // the quartiles land at each regime's internal median
        let q25: f64 = quantile_key_weighted(&[(&low, 1.0), (&high, 1.0)], 0.25);
        let q50: f64 = quantile_key_weighted(&[(&low, 1.0), (&high, 1.0)], 0.5);
        let q75: f64 = quantile_key_weighted(&[(&low, 1.0), (&high, 1.0)], 0.75);
        assert!((q25 - 5e4).abs() < 1e4, "q25={q25}");
        assert!((9e4..=9.2e5).contains(&q50), "q50={q50}");
        assert!((q75 - 9.5e5).abs() < 1e4, "q75={q75}");
        // 3:1 weights shift the median into the heavier regime
        let m: f64 = quantile_key_weighted(&[(&low, 3.0), (&high, 1.0)], 0.5);
        assert!(m < 1e5, "median {m} must fall inside the 3x regime");
        // single-model mixture == quantile_key (same search, same key)
        let a: f64 = quantile_key_weighted(&[(&low, 7.0)], 0.3);
        let b: f64 = quantile_key(&low, 0.3);
        assert_eq!(a.to_bits(), b.to_bits());
        // non-positive weights are ignored, not poisoning the sum
        let c: f64 = quantile_key_weighted(&[(&low, 1.0), (&high, -5.0)], 0.5);
        assert!((c - 5e4).abs() < 1e4, "c={c}");
    }

    #[test]
    fn empirical_only_mixture_recovers_sample_quantiles() {
        // a pure-fallback stream: no models at all, only the sampled keys
        let sample: Vec<f64> = (1..=100).map(|i| i as f64 * 10.0).collect();
        let bits: Vec<u64> = sample.iter().map(|k| k.to_bits_ordered()).collect();
        for q in [0.1, 0.25, 0.5, 0.75, 0.9] {
            let k: f64 = quantile_key_mixture(&[], Some((&bits, 1.0)), q);
            // the step CDF jumps to q exactly at the ceil(q*n)-th sample key
            let expect = sample[(q * 100.0).ceil() as usize - 1];
            assert!(
                (k - expect).abs() < 1e-9,
                "q={q}: key {k} != sample quantile {expect}"
            );
        }
        // empty sample / non-positive weight: inert, falls back to models
        let mut rng = Xoshiro256pp::new(0xE3);
        let mut s: Vec<f64> = (0..8192).map(|_| rng.uniform(0.0, 1e6)).collect();
        s.sort_unstable_by(f64::total_cmp);
        let rmi = Rmi::train(&s, RmiConfig { n_leaves: 128 });
        let base: f64 = quantile_key_weighted(&[(&rmi, 1.0)], 0.5);
        let empty: f64 = quantile_key_mixture(&[(&rmi, 1.0)], Some((&[], 1.0)), 0.5);
        let zero_w: f64 = quantile_key_mixture(&[(&rmi, 1.0)], Some((&bits, 0.0)), 0.5);
        assert_eq!(base.to_bits(), empty.to_bits());
        assert_eq!(base.to_bits(), zero_w.to_bits());
    }

    #[test]
    fn empirical_component_pulls_cuts_toward_fallback_regime() {
        let mut rng = Xoshiro256pp::new(0x5A17);
        // the learned model only saw the low regime ...
        let mut s: Vec<f64> = (0..8192).map(|_| rng.uniform(0.0, 1e5)).collect();
        s.sort_unstable_by(f64::total_cmp);
        let low = Rmi::train(&s, RmiConfig { n_leaves: 128 });
        // ... while the fallback chunks all live in a high regime
        let mut high_bits: Vec<u64> = (0..2048)
            .map(|_| rng.uniform(9e5, 1e6).to_bits_ordered())
            .collect();
        high_bits.sort_unstable();
        let without: f64 = quantile_key_weighted(&[(&low, 1.0)], 0.5);
        let with: f64 =
            quantile_key_mixture(&[(&low, 1.0)], Some((&high_bits, 1.0)), 0.5);
        // model alone cuts inside the low regime; the equal-mass empirical
        // component pushes the median to the boundary between regimes
        assert!(without < 1.1e5, "without={without}");
        assert!(with > 9e4, "with={with}");
        // and the 75% cut lands inside the fallback regime itself
        let q75: f64 =
            quantile_key_mixture(&[(&low, 1.0)], Some((&high_bits, 1.0)), 0.75);
        assert!((9e5..=1e6).contains(&q75), "q75={q75}");
    }

    #[test]
    fn learned_pivots_nondecreasing() {
        let mut rng = Xoshiro256pp::new(9);
        let keys: Vec<f64> = (0..50_000).map(|_| rng.lognormal(0.0, 0.5)).collect();
        let rmi = Rmi::train_from_keys(&keys, 1024, RmiConfig { n_leaves: 128 }, &mut rng);
        let pivots = learned_pivots(&rmi, &keys, 64);
        let present: Vec<f64> = pivots.iter().flatten().copied().collect();
        for w in present.windows(2) {
            assert!(w[0] <= w[1], "pivots must be nondecreasing");
        }
    }
}
