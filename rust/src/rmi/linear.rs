//! Closed-form simple linear regression used by both RMI levels.

/// Accumulated sufficient statistics for a least-squares line fit:
/// (count, Σx, Σy, Σxy, Σx²) — the same 5-tuple the Pallas training kernel
/// produces per leaf.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FitStats {
    /// Number of points.
    pub cnt: f64,
    /// Σx.
    pub sx: f64,
    /// Σy.
    pub sy: f64,
    /// Σxy.
    pub sxy: f64,
    /// Σx².
    pub sxx: f64,
}

impl FitStats {
    /// Fold one point into the statistics.
    #[inline]
    pub fn add(&mut self, x: f64, y: f64) {
        self.cnt += 1.0;
        self.sx += x;
        self.sy += y;
        self.sxy += x * y;
        self.sxx += x * x;
    }

    /// Combine with statistics accumulated elsewhere (parallel slices).
    #[inline]
    pub fn merge(&mut self, o: &FitStats) {
        self.cnt += o.cnt;
        self.sx += o.sx;
        self.sy += o.sy;
        self.sxy += o.sxy;
        self.sxx += o.sxx;
    }

    /// Least-squares slope/intercept with the *monotone* constraint
    /// slope >= 0 (the root and leaves of the RMI must be nondecreasing).
    /// Degenerate inputs (fewer than 2 points, zero variance) fall back to
    /// the constant fit (slope 0, intercept = mean y) — identical to
    /// `ref_fit_leaves` in python/compile/kernels/ref.py.
    pub fn fit_monotone(&self) -> (f64, f64) {
        let denom = self.cnt * self.sxx - self.sx * self.sx;
        let ok = self.cnt >= 2.0 && denom.abs() > 1e-30;
        let mut a = if ok {
            (self.cnt * self.sxy - self.sx * self.sy) / denom
        } else {
            0.0
        };
        if a < 0.0 {
            a = 0.0;
        }
        let b = if self.cnt > 0.0 {
            (self.sy - a * self.sx) / self.cnt
        } else {
            0.0
        };
        (a, b)
    }
}

/// Fit y = a*x + b over parallel slices (monotone-constrained).
pub fn fit_line_monotone(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    debug_assert_eq!(xs.len(), ys.len());
    let mut st = FitStats::default();
    for (&x, &y) in xs.iter().zip(ys) {
        st.add(x, y);
    }
    st.fit_monotone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 2.0).collect();
        let (a, b) = fit_line_monotone(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn negative_slope_clamped_to_constant() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [3.0, 2.0, 1.0, 0.0];
        let (a, b) = fit_line_monotone(&xs, &ys);
        assert_eq!(a, 0.0);
        assert!((b - 1.5).abs() < 1e-12); // mean of ys
    }

    #[test]
    fn degenerate_inputs() {
        let (a, b) = fit_line_monotone(&[], &[]);
        assert_eq!((a, b), (0.0, 0.0));
        let (a, b) = fit_line_monotone(&[5.0], &[0.25]);
        assert_eq!(a, 0.0);
        assert_eq!(b, 0.25);
        // zero x-variance
        let (a, b) = fit_line_monotone(&[2.0, 2.0, 2.0], &[0.1, 0.2, 0.3]);
        assert_eq!(a, 0.0);
        assert!((b - 0.2).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_bulk() {
        let xs: Vec<f64> = (0..50).map(|i| (i * 7 % 13) as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 0.5 * x + 1.0).collect();
        let mut a = FitStats::default();
        let mut b = FitStats::default();
        for i in 0..xs.len() {
            if i % 2 == 0 {
                a.add(xs[i], ys[i]);
            } else {
                b.add(xs[i], ys[i]);
            }
        }
        a.merge(&b);
        let mut bulk = FitStats::default();
        for i in 0..xs.len() {
            bulk.add(xs[i], ys[i]);
        }
        assert!((a.fit_monotone().0 - bulk.fit_monotone().0).abs() < 1e-12);
    }
}
