//! IPS⁴o — In-place Parallel Super Scalar SampleSort (engine E1), after
//! Axtmann, Witt, Ferizovic & Sanders, "Engineering In-Place
//! (Shared-Memory) Sorting Algorithms", ACM TOPC 2022.
//!
//! Pipeline per recursion step: draw an oversampled random sample, build
//! the branchless splitter [`DecisionTree`] (equality buckets switch on
//! when the sample shows duplicates), run the three-phase in-place block
//! [`partition`], then recurse into non-equality buckets. Small inputs go
//! to the introsort base case; a depth limit guards the (sample-unlucky)
//! worst case with heapsort.
//!
//! The parallel driver partitions the top level cooperatively (all threads
//! classify + permute together), then feeds buckets to the task-pool
//! scheduler; large sub-buckets re-partition and spawn their children as
//! new tasks.

pub mod base_case;
pub mod config;
pub mod partition;

pub use config::SampleSortConfig;
pub use partition::{partition, PartitionResult};

use crate::classifier::decision_tree::DecisionTree;
use crate::classifier::Classifier;
use crate::key::SortKey;
use crate::scheduler::run_task_pool;
use crate::util::rng::Xoshiro256pp;
use crate::util::timer::{phase_scope, Phase};

/// Sort sequentially with default config (paper name: I1S⁴o).
pub fn sort_seq<K: SortKey>(data: &mut [K]) {
    sort_seq_cfg(data, &SampleSortConfig::default());
}

/// Sequential IPS⁴o with explicit configuration.
pub fn sort_seq_cfg<K: SortKey>(data: &mut [K], cfg: &SampleSortConfig) {
    let mut rng = Xoshiro256pp::new(0x1B54_0001 ^ data.len() as u64);
    sort_rec(data, cfg, cfg.max_depth, &mut rng, 1);
}

/// Sort with `threads` workers (paper name: IPS⁴o).
pub fn sort_par<K: SortKey>(data: &mut [K], threads: usize) {
    sort_par_cfg(data, threads, &SampleSortConfig::default());
}

/// Parallel IPS⁴o with explicit configuration.
pub fn sort_par_cfg<K: SortKey>(data: &mut [K], threads: usize, cfg: &SampleSortConfig) {
    let threads = threads.max(1);
    let n = data.len();
    if threads == 1 || n <= cfg.base_case.max(4 * cfg.block * threads) {
        return sort_seq_cfg(data, cfg);
    }
    let mut rng = Xoshiro256pp::new(0x1B54_0002 ^ n as u64);
    // Top level: cooperative partition by all threads.
    let Some(tree) = build_tree(data, cfg, &mut rng) else {
        // degenerate sample (all keys equal) — nothing to sort
        return;
    };
    let result = partition(data, &tree, cfg.block, threads);

    // Sub-buckets become tasks; each task sorts its range sequentially but
    // may spawn its own sub-buckets when it re-partitions (depth-first
    // LIFO pool = IPS⁴o's sub-problem scheduler).
    let base = data.as_mut_ptr() as usize;
    let cfg = *cfg;
    let mut tasks: Vec<(usize, usize, usize)> = Vec::new(); // (offset, len, depth)
    for b in 0..tree.num_buckets() {
        let (lo, hi) = (result.boundaries[b], result.boundaries[b + 1]);
        if hi - lo > 1 && !tree.is_equality_bucket(b) {
            tasks.push((lo, hi - lo, cfg.max_depth - 1));
        }
    }
    run_task_pool(threads, tasks, move |(off, len, depth), spawner| {
        // SAFETY: task ranges are disjoint sub-ranges of `data`, produced
        // only by partition boundaries.
        let sub = unsafe { std::slice::from_raw_parts_mut((base as *mut K).add(off), len) };
        if len <= cfg.base_case || depth == 0 {
            let _g = phase_scope(Phase::BaseCase);
            if depth == 0 {
                base_case::heapsort(sub);
            } else {
                base_case::small_sort(sub);
            }
            return;
        }
        let mut rng = Xoshiro256pp::stream(0x1B54_0003, off as u64);
        let Some(tree) = build_tree(sub, &cfg, &mut rng) else {
            return;
        };
        let res = partition(sub, &tree, cfg.block, 1);
        for b in 0..tree.num_buckets() {
            let (lo, hi) = (res.boundaries[b], res.boundaries[b + 1]);
            if hi - lo > 1 && !tree.is_equality_bucket(b) {
                spawner.spawn((off + lo, hi - lo, depth - 1));
            }
        }
    });
}

/// Sequential recursion.
fn sort_rec<K: SortKey>(
    data: &mut [K],
    cfg: &SampleSortConfig,
    depth: usize,
    rng: &mut Xoshiro256pp,
    threads: usize,
) {
    let n = data.len();
    if n <= cfg.base_case {
        let _g = phase_scope(Phase::BaseCase);
        base_case::small_sort(data);
        return;
    }
    if depth == 0 {
        let _g = phase_scope(Phase::BaseCase);
        base_case::heapsort(data);
        return;
    }
    let Some(tree) = build_tree(data, cfg, rng) else {
        return; // all sampled keys equal and no distinct keys found
    };
    let result = partition(data, &tree, cfg.block, threads);
    for b in 0..tree.num_buckets() {
        let (lo, hi) = (result.boundaries[b], result.boundaries[b + 1]);
        if hi - lo > 1 && !tree.is_equality_bucket(b) {
            sort_rec(&mut data[lo..hi], cfg, depth - 1, rng, 1);
        }
    }
}

/// Draw + sort the sample, build the splitter tree. Returns `None` when
/// the whole input is a single repeated key (already sorted).
fn build_tree<K: SortKey>(
    data: &[K],
    cfg: &SampleSortConfig,
    rng: &mut Xoshiro256pp,
) -> Option<DecisionTree<K>> {
    let _g = phase_scope(Phase::Sampling);
    let n = data.len();
    let k = cfg.effective_buckets(n);
    let ssz = cfg.sample_size_for(n, k);
    let mut sample: Vec<K> = (0..ssz)
        .map(|_| data[rng.next_below(n as u64) as usize])
        .collect();
    sample.sort_unstable_by(|a, b| a.to_bits_ordered().cmp(&b.to_bits_ordered()));
    if sample.first().map(|k| k.to_bits_ordered()) == sample.last().map(|k| k.to_bits_ordered()) {
        // sample is constant — verify against the data before skipping
        let v = sample.first()?.to_bits_ordered();
        if data.iter().all(|k| k.to_bits_ordered() == v) {
            return None;
        }
    }
    Some(DecisionTree::from_sorted_sample(&sample, k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::is_sorted;
    use crate::util::rng::Xoshiro256pp;

    fn random_u64(n: usize, universe: u64, seed: u64) -> Vec<u64> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..n).map(|_| rng.next_below(universe)).collect()
    }

    #[test]
    fn seq_sorts_sizes() {
        for n in [0usize, 1, 2, 100, 1024, 1025, 10_000, 100_000] {
            let mut v = random_u64(n, u64::MAX, n as u64 + 1);
            let mut want = v.clone();
            want.sort_unstable();
            sort_seq(&mut v);
            assert_eq!(v, want, "n={n}");
        }
    }

    #[test]
    fn par_sorts_sizes_and_threads() {
        for (n, t) in [(10_000usize, 2usize), (100_000, 4), (250_000, 8), (99_999, 3)] {
            let mut v = random_u64(n, 1 << 50, n as u64);
            let mut want = v.clone();
            want.sort_unstable();
            sort_par(&mut v, t);
            assert_eq!(v, want, "n={n} t={t}");
        }
    }

    #[test]
    fn duplicate_adversaries() {
        // RootDups-style and constant arrays
        for t in [1usize, 4] {
            let n = 100_000;
            let m = (n as f64).sqrt() as u64;
            let mut v: Vec<u64> = (0..n as u64).map(|i| i % m).collect();
            let mut want = v.clone();
            want.sort_unstable();
            sort_par(&mut v, t);
            assert_eq!(v, want);

            let mut c = vec![3u64; n];
            sort_par(&mut c, t);
            assert!(c.iter().all(|&x| x == 3));
        }
    }

    #[test]
    fn few_distinct_values() {
        let mut v = random_u64(50_000, 3, 9);
        let mut want = v.clone();
        want.sort_unstable();
        sort_seq(&mut v);
        assert_eq!(v, want);
    }

    #[test]
    fn floats_including_negatives() {
        let mut rng = Xoshiro256pp::new(31);
        let mut v: Vec<f64> = (0..120_000).map(|_| rng.normal() * 1e4).collect();
        sort_par(&mut v, 4);
        assert!(is_sorted(&v));
    }

    #[test]
    fn already_sorted_and_reversed() {
        let mut v: Vec<u64> = (0..80_000).collect();
        sort_seq(&mut v);
        assert!(is_sorted(&v));
        let mut v: Vec<u64> = (0..80_000).rev().collect();
        sort_par(&mut v, 4);
        assert!(is_sorted(&v));
    }
}
