//! Base-case sorters (substrate S6): insertion sort, heapsort and a
//! median-of-3 introsort used below the partitioning thresholds.
//!
//! All comparisons run under the key's *full* order
//! ([`SortKey::key_lt`]/[`SortKey::key_cmp`]). For the numeric key types
//! that is exactly the ordered-bits compare it always was; for
//! prefix-encoded string keys (and records over them) it additionally
//! breaks prefix-collided bits on the tail, so base cases come out fully
//! sorted with no separate tie-repair pass.

use crate::key::SortKey;

/// Insertion sort — the paper's base case for Quicksort/LearnedSort, and
/// the repair pass of LearnedSort (cheap on almost-sorted input).
pub fn insertion_sort<K: SortKey>(data: &mut [K]) {
    for i in 1..data.len() {
        let x = data[i];
        let mut j = i;
        while j > 0 && x.key_lt(data[j - 1]) {
            data[j] = data[j - 1];
            j -= 1;
        }
        data[j] = x;
    }
}

/// Bottom-up heapsort — the IntroSort fallback guaranteeing O(N log N)
/// whatever the pivots do (Musser '97; paper Section 2.3).
pub fn heapsort<K: SortKey>(data: &mut [K]) {
    let n = data.len();
    if n < 2 {
        return;
    }
    for i in (0..n / 2).rev() {
        sift_down(data, i, n);
    }
    for end in (1..n).rev() {
        data.swap(0, end);
        sift_down(data, 0, end);
    }
}

fn sift_down<K: SortKey>(data: &mut [K], mut root: usize, end: usize) {
    loop {
        let mut child = 2 * root + 1;
        if child >= end {
            return;
        }
        if child + 1 < end && data[child].key_lt(data[child + 1]) {
            child += 1;
        }
        if !data[root].key_lt(data[child]) {
            return;
        }
        data.swap(root, child);
        root = child;
    }
}

/// Threshold below which introsort switches to insertion sort.
pub const INSERTION_THRESHOLD: usize = 24;

/// The engines' small-input sorter. Delegates to the stdlib pdqsort over
/// the order-preserving bit image — the same algorithm the paper cites as
/// the Rust stdlib unstable sort (Section 2.3), and ~1.7x faster than our
/// own introsort at base-case sizes (perf log, EXPERIMENTS.md §Perf).
/// [`introsort`] below remains as the dependency-free reference.
#[inline]
pub fn small_sort<K: SortKey>(data: &mut [K]) {
    if K::ORDER_IN_BITS {
        data.sort_unstable_by_key(|k| k.to_bits_ordered());
    } else {
        // coarse-bits keys (string prefixes): pdqsort under the full
        // comparator so prefix ties land tail-ordered
        data.sort_unstable_by(|a, b| a.key_cmp(*b));
    }
}

/// Median-of-3 introsort: quicksort with a depth limit falling back to
/// heapsort, insertion sort at the bottom.
pub fn introsort<K: SortKey>(data: &mut [K]) {
    let n = data.len();
    if n < 2 {
        return;
    }
    let depth_limit = 2 * (usize::BITS - n.leading_zeros()) as usize;
    introsort_rec(data, depth_limit);
}

fn introsort_rec<K: SortKey>(data: &mut [K], depth: usize) {
    let n = data.len();
    if n <= INSERTION_THRESHOLD {
        insertion_sort(data);
        return;
    }
    if depth == 0 {
        heapsort(data);
        return;
    }
    let p = partition_mo3(data);
    let (lo, hi) = data.split_at_mut(p);
    introsort_rec(lo, depth - 1);
    introsort_rec(&mut hi[1..], depth - 1);
}

/// Hoare-style partition around the median of first/middle/last.
/// Returns the final pivot index; equal keys split between sides.
fn partition_mo3<K: SortKey>(data: &mut [K]) -> usize {
    let n = data.len();
    let mid = n / 2;
    // median of three into data[0]
    if data[mid].key_lt(data[0]) {
        data.swap(mid, 0);
    }
    if data[n - 1].key_lt(data[0]) {
        data.swap(n - 1, 0);
    }
    if data[n - 1].key_lt(data[mid]) {
        data.swap(n - 1, mid);
    }
    data.swap(0, mid); // pivot to front
    let pivot = data[0];
    // Lomuto-with-swaps
    let mut i = 1usize;
    for j in 1..n {
        if data[j].key_lt(pivot) {
            data.swap(i, j);
            i += 1;
        }
    }
    data.swap(0, i - 1);
    i - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn check_sorts(f: fn(&mut [u64])) {
        let mut rng = Xoshiro256pp::new(0xBA5E);
        for n in [0usize, 1, 2, 3, 10, 24, 25, 100, 1000, 4097] {
            let mut v: Vec<u64> = (0..n as u64).map(|_| rng.next_below(1000)).collect();
            let mut want = v.clone();
            want.sort_unstable();
            f(&mut v);
            assert_eq!(v, want, "n={n}");
        }
    }

    #[test]
    fn insertion_sorts() {
        check_sorts(insertion_sort::<u64>);
    }

    #[test]
    fn heapsort_sorts() {
        check_sorts(heapsort::<u64>);
    }

    #[test]
    fn introsort_sorts() {
        check_sorts(introsort::<u64>);
    }

    #[test]
    fn small_sort_sorts() {
        check_sorts(small_sort::<u64>);
    }

    #[test]
    fn sorts_floats_with_negatives() {
        let mut rng = Xoshiro256pp::new(0xF10A7);
        let mut v: Vec<f64> = (0..5000).map(|_| rng.normal() * 100.0).collect();
        v.push(-0.0);
        v.push(0.0);
        let mut want = v.clone();
        want.sort_unstable_by(f64::total_cmp);
        small_sort(&mut v);
        assert_eq!(
            v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn adversarial_patterns() {
        for n in [100usize, 1000] {
            // already sorted, reversed, all-equal, organ pipe
            let mut cases: Vec<Vec<u64>> = vec![
                (0..n as u64).collect(),
                (0..n as u64).rev().collect(),
                vec![7; n],
            ];
            let mut pipe: Vec<u64> = (0..n as u64 / 2).collect();
            pipe.extend((0..n as u64 / 2).rev());
            cases.push(pipe);
            for mut v in cases {
                let mut want = v.clone();
                want.sort_unstable();
                small_sort(&mut v);
                assert_eq!(v, want);
            }
        }
    }
}
