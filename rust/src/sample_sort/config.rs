//! Tuning knobs for the SampleSort family (defaults follow IPS⁴o's
//! published constants, scaled for 8-byte keys).

/// Tuning knobs of the IPS⁴o implementation.
#[derive(Debug, Clone, Copy)]
pub struct SampleSortConfig {
    /// Base fan-out k (buckets before equality doubling). IPS⁴o: 256.
    pub buckets: usize,
    /// Keys per block / per bucket buffer. IPS⁴o uses 2 KiB blocks for
    /// 8-byte keys (256 keys); 128 keeps k·block buffers cache-friendly.
    pub block: usize,
    /// Below this, use the base-case sorter instead of partitioning.
    pub base_case: usize,
    /// Oversampling factor: sample = oversample * buckets keys.
    pub oversample: usize,
    /// Recursion depth limit before the heapsort fallback (IntroSort
    /// safety net; IPS⁴o relies on equality buckets instead, we keep both).
    pub max_depth: usize,
}

impl Default for SampleSortConfig {
    fn default() -> Self {
        SampleSortConfig {
            buckets: 256,
            block: 128,
            base_case: 1024,
            oversample: 8,
            max_depth: 12,
        }
    }
}

impl SampleSortConfig {
    /// Fan-out for an input of n keys: the configured k, shrunk so buckets
    /// land near `base_case` size. Without this, small sub-problems pay
    /// full-k sampling + buffer setup — the dominant overhead at depth > 1
    /// (perf log, EXPERIMENTS.md §Perf).
    pub fn effective_buckets(&self, n: usize) -> usize {
        let want = (n / self.base_case).max(2).next_power_of_two();
        want.min(self.buckets).max(2)
    }

    /// Sample size for an input of n keys at fan-out k.
    pub fn sample_size_for(&self, n: usize, k: usize) -> usize {
        (self.oversample * k).min(n.max(1))
    }

    /// Sample size at the full configured fan-out (top level).
    pub fn sample_size(&self, n: usize) -> usize {
        self.sample_size_for(n, self.effective_buckets(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = SampleSortConfig::default();
        assert!(c.buckets.is_power_of_two());
        assert!(c.base_case >= 2 * c.block);
        assert_eq!(c.sample_size(10), 10);
        assert_eq!(c.sample_size(1 << 20), 8 * 256);
    }
}
