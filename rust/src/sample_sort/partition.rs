//! The in-place block partitioning framework of IPS⁴o (substrate S4) —
//! shared by IPS⁴o itself, IPS²Ra (digit classifier) and AIPS²o (RMI
//! classifier).
//!
//! Three phases, exactly as Axtmann et al. describe (TOPC '22, §4):
//!
//! 1. **Local classification.** Each thread walks its stripe keeping one
//!    `block`-sized buffer per bucket; full buffers flush as *blocks* into
//!    the already-consumed prefix of the stripe (never overtaking the read
//!    cursor), so the input is overwritten in place.
//! 2. **Block permutation.** Blocks move to their bucket's block-aligned
//!    destination window with chain-following swaps; write cursors are
//!    per-bucket atomics (`fetch_add`), so all threads permute
//!    cooperatively. A block whose destination is the partial tail slot
//!    goes to the single overflow buffer (IPS⁴o's overflow case).
//! 3. **Cleanup.** Per bucket: keys that spilled past the bucket's end
//!    (into the next bucket's head), the overflow block, and the partial
//!    buffers fill the bucket's unaligned head and tail.
//!
//! Deviation from IPS⁴o noted in DESIGN.md §6: we keep one atomic state
//! byte per block (`O(N/block)` extra bytes) instead of IPS⁴o's strictly
//! O(k·block) bookkeeping; every block is still read and written exactly
//! once, which is what the memory-traffic shape depends on.

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::classifier::Classifier;
use crate::key::SortKey;
use crate::scheduler::parallel_for;
use crate::util::timer::{phase_scope, Phase};

const ST_UNMOVED: u8 = 0;
const ST_FREE: u8 = 1;
const ST_CLAIMED: u8 = 2;
const ST_DONE: u8 = 3;

/// Per-thread bucket buffers (one `block` of keys per bucket).
struct ThreadBuffers<K> {
    data: Vec<K>,
    lens: Vec<u32>,
    block: usize,
}

impl<K: SortKey> ThreadBuffers<K> {
    fn new(nb: usize, block: usize, fill: K) -> Self {
        ThreadBuffers {
            data: vec![fill; nb * block],
            lens: vec![0; nb],
            block,
        }
    }

    #[inline(always)]
    fn bucket(&self, b: usize) -> &[K] {
        &self.data[b * self.block..b * self.block + self.lens[b] as usize]
    }
}

/// Result of one partition pass.
pub struct PartitionResult {
    /// `boundaries[b]..boundaries[b+1]` is bucket `b`; length `nb + 1`.
    pub boundaries: Vec<usize>,
}

/// Partition `data` into `classifier.num_buckets()` ordered buckets with
/// `threads` cooperating workers. Returns bucket boundaries.
pub fn partition<K: SortKey, C: Classifier<K> + ?Sized>(
    data: &mut [K],
    classifier: &C,
    block: usize,
    threads: usize,
) -> PartitionResult {
    let n = data.len();
    let nb = classifier.num_buckets();
    assert!(nb >= 2);
    assert!(block >= 1);
    if n == 0 {
        return PartitionResult {
            boundaries: vec![0; nb + 1],
        };
    }
    let threads = threads.max(1);
    let n_slots = n.div_ceil(block);
    // Stripes are whole numbers of slots so flushed blocks stay aligned.
    let workers = threads.min(n_slots.max(1));
    let slots_per_stripe = n_slots.div_ceil(workers);

    // ---- Phase 1: local classification ------------------------------
    let _g = phase_scope(Phase::Classification);
    let fill = data[0];
    let mut stripe_results: Vec<Option<StripeOut<K>>> = Vec::new();
    stripe_results.resize_with(workers, || None);
    {
        let results = Mutex::new(&mut stripe_results);
        let data_ptr = SendPtr(data.as_mut_ptr());
        parallel_for(workers, workers, |_, range| {
            for t in range {
                let slot_lo = t * slots_per_stripe;
                let slot_hi = ((t + 1) * slots_per_stripe).min(n_slots);
                if slot_lo >= slot_hi {
                    continue;
                }
                let lo = slot_lo * block;
                let hi = (slot_hi * block).min(n);
                // SAFETY: stripes are disjoint index ranges of `data`.
                let stripe =
                    unsafe { std::slice::from_raw_parts_mut(data_ptr.get().add(lo), hi - lo) };
                let out = classify_stripe(stripe, classifier, nb, block, fill, slot_lo);
                results.lock().unwrap()[t] = Some(out);
            }
        });
    }
    drop(_g);
    let stripes: Vec<StripeOut<K>> = stripe_results.into_iter().flatten().collect();

    // ---- Aggregate counts -> boundaries + write cursors --------------
    let mut counts = vec![0usize; nb];
    for s in &stripes {
        for (c, sc) in counts.iter_mut().zip(&s.counts) {
            *c += sc;
        }
    }
    debug_assert_eq!(counts.iter().sum::<usize>(), n);
    let mut boundaries = vec![0usize; nb + 1];
    for b in 0..nb {
        boundaries[b + 1] = boundaries[b] + counts[b];
    }

    // ---- Phase 2: block permutation ----------------------------------
    let _g = phase_scope(Phase::BlockPermutation);
    // Slot states: UNMOVED inside each stripe's flushed prefix, FREE after.
    let state: Vec<AtomicU8> = (0..n_slots).map(|_| AtomicU8::new(ST_FREE)).collect();
    for s in &stripes {
        for slot in s.first_slot..s.first_slot + s.flushed {
            state[slot].store(ST_UNMOVED, Ordering::Relaxed);
        }
    }
    // Per-bucket write cursors at round_up(start, block).
    let cursors: Vec<AtomicUsize> = boundaries[..nb]
        .iter()
        .map(|&s| AtomicUsize::new(s.div_ceil(block)))
        .collect();
    let overflow: Mutex<Option<(usize, Vec<K>)>> = Mutex::new(None);
    {
        let data_ptr = SendPtr(data.as_mut_ptr());
        let state_ref = &state;
        let cursors_ref = &cursors;
        let overflow_ref = &overflow;
        parallel_for(workers, n_slots, |_, slot_range| {
            let mut tmp: Vec<K> = vec![fill; block];
            let mut tmp2: Vec<K> = vec![fill; block];
            for s0 in slot_range {
                if state_ref[s0].load(Ordering::Relaxed) != ST_UNMOVED {
                    continue;
                }
                if state_ref[s0]
                    .compare_exchange(
                        ST_UNMOVED,
                        ST_CLAIMED,
                        Ordering::Acquire,
                        Ordering::Relaxed,
                    )
                    .is_err()
                {
                    continue;
                }
                // SAFETY: we own slot s0 (CLAIMED); it is a full block.
                unsafe {
                    std::ptr::copy_nonoverlapping(data_ptr.get().add(s0 * block), tmp.as_mut_ptr(), block);
                }
                state_ref[s0].store(ST_FREE, Ordering::Release);
                let mut b = classifier.classify(tmp[0]);
                // Chain: place `tmp`, displacing whatever occupies the slot.
                loop {
                    let d = cursors_ref[b].fetch_add(1, Ordering::Relaxed);
                    if d * block + block > n {
                        // destination is the partial tail slot -> overflow
                        let mut ov = overflow_ref.lock().unwrap();
                        debug_assert!(ov.is_none(), "more than one overflow block");
                        *ov = Some((b, tmp[..block].to_vec()));
                        break;
                    }
                    if state_ref[d]
                        .compare_exchange(
                            ST_UNMOVED,
                            ST_CLAIMED,
                            Ordering::Acquire,
                            Ordering::Relaxed,
                        )
                        .is_ok()
                    {
                        // Displace the unmoved block at d, then write ours.
                        unsafe {
                            std::ptr::copy_nonoverlapping(
                                data_ptr.get().add(d * block),
                                tmp2.as_mut_ptr(),
                                block,
                            );
                            std::ptr::copy_nonoverlapping(
                                tmp.as_ptr(),
                                data_ptr.get().add(d * block),
                                block,
                            );
                        }
                        state_ref[d].store(ST_DONE, Ordering::Release);
                        std::mem::swap(&mut tmp, &mut tmp2);
                        b = classifier.classify(tmp[0]);
                        continue;
                    }
                    // Wait out a concurrent reader, then take the free slot.
                    loop {
                        match state_ref[d].load(Ordering::Acquire) {
                            ST_FREE => break,
                            ST_CLAIMED => std::hint::spin_loop(),
                            st => unreachable!("slot {d} in state {st} cannot be a destination"),
                        }
                    }
                    unsafe {
                        std::ptr::copy_nonoverlapping(tmp.as_ptr(), data_ptr.get().add(d * block), block);
                    }
                    state_ref[d].store(ST_DONE, Ordering::Release);
                    break;
                }
            }
        });
    }
    drop(_g);

    // ---- Phase 3: cleanup --------------------------------------------
    let _g = phase_scope(Phase::Cleanup);
    let overflow = overflow.into_inner().unwrap();
    // Blocks actually written in-array per bucket = cursor - initial,
    // minus the overflow block if it was this bucket's.
    let written: Vec<usize> = (0..nb)
        .map(|b| {
            let first = boundaries[b].div_ceil(block);
            let cur = cursors[b].load(Ordering::Relaxed);
            let mut w = cur.saturating_sub(first);
            if let Some((ob, _)) = &overflow {
                if *ob == b && w > 0 {
                    w -= 1;
                }
            }
            w
        })
        .collect();

    // 3a: copy each bucket's spill (keys past its end) out of the array.
    let mut spills: Vec<Vec<K>> = vec![Vec::new(); nb];
    {
        let spills_mx = Mutex::new(&mut spills);
        let data_ref = &*data;
        let boundaries_ref = &boundaries;
        let written_ref = &written;
        parallel_for(workers, nb, |_, brange| {
            for b in brange {
                let start = boundaries_ref[b];
                let end = boundaries_ref[b + 1];
                if start == end || written_ref[b] == 0 {
                    continue;
                }
                // blocks written in-array occupy [ub, blocks_end); any part
                // past the bucket end is spill (it sits in the next
                // bucket's head area)
                let ub = start.div_ceil(block) * block;
                let blocks_end = ub + written_ref[b] * block;
                debug_assert!(blocks_end <= n);
                if blocks_end > end {
                    let spill = data_ref[end.max(ub)..blocks_end].to_vec();
                    spills_mx.lock().unwrap()[b] = spill;
                }
            }
        });
    }

    // 3b: fill each bucket's head + tail from spill/overflow/buffers.
    {
        let data_ptr = SendPtr(data.as_mut_ptr());
        let boundaries_ref = &boundaries;
        let written_ref = &written;
        let spills_ref = &spills;
        let stripes_ref = &stripes;
        let overflow_ref = &overflow;
        parallel_for(workers, nb, |_, brange| {
            for b in brange {
                let start = boundaries_ref[b];
                let end = boundaries_ref[b + 1];
                if start == end {
                    continue;
                }
                let ub_raw = start.div_ceil(block) * block;
                let ub = ub_raw.min(end);
                // in-region end of the written blocks (== ub when none)
                let blocks_end = if written_ref[b] > 0 {
                    (ub_raw + written_ref[b] * block).min(end)
                } else {
                    ub
                };
                // positions to fill
                let head = start..ub;
                let tail = blocks_end.max(ub)..end;
                // SAFETY: head/tail lie inside bucket b's region; buckets
                // are disjoint across parallel iterations.
                let mut positions = head.chain(tail);
                let mut write = |k: K| {
                    let p = positions.next().expect("more fill keys than fill positions");
                    unsafe { data_ptr.get().add(p).write(k) };
                };
                for &k in &spills_ref[b] {
                    write(k);
                }
                if let Some((ob, ovk)) = overflow_ref {
                    if *ob == b {
                        for &k in ovk {
                            write(k);
                        }
                    }
                }
                for s in stripes_ref {
                    for &k in s.buffers.bucket(b) {
                        write(k);
                    }
                }
                assert!(
                    positions.next().is_none(),
                    "bucket {b}: fill positions left over"
                );
            }
        });
    }
    drop(_g);

    PartitionResult { boundaries }
}

struct StripeOut<K> {
    first_slot: usize,
    flushed: usize,
    counts: Vec<usize>,
    buffers: ThreadBuffers<K>,
}

/// Phase 1 worker: classify one stripe, flushing full buffers as blocks
/// into the stripe's own consumed prefix.
fn classify_stripe<K: SortKey, C: Classifier<K> + ?Sized>(
    stripe: &mut [K],
    classifier: &C,
    nb: usize,
    block: usize,
    fill: K,
    first_slot: usize,
) -> StripeOut<K> {
    let mut buffers = ThreadBuffers::new(nb, block, fill);
    let mut counts = vec![0usize; nb];
    let mut flushed = 0usize;
    const BATCH: usize = 512;
    let mut idx = [0u32; BATCH];
    let mut read = 0usize;
    let n = stripe.len();
    while read < n {
        let m = BATCH.min(n - read);
        // Batched classification first (ILP), then buffer pushes.
        classifier.classify_batch(&stripe[read..read + m], &mut idx[..m]);
        for i in 0..m {
            let b = idx[i] as usize;
            debug_assert!(b < nb);
            let key = stripe[read + i];
            // SAFETY: b < nb (classifier contract, checked in debug);
            // len < block by the flush invariant below. Bounds checks here
            // cost ~10% of the classification phase (perf log §Perf).
            let len = unsafe { *buffers.lens.get_unchecked(b) } as usize;
            unsafe {
                *buffers.data.get_unchecked_mut(b * block + len) = key;
                *buffers.lens.get_unchecked_mut(b) = (len + 1) as u32;
                *counts.get_unchecked_mut(b) += 1;
            }
            if len + 1 == block {
                // Flush: write the full buffer into the consumed prefix.
                // write pos = flushed blocks so far; invariant
                // flushed*block + buffered <= consumed keys (= read+i+1).
                let dst = flushed * block;
                // invariant: flushed blocks never overtake the read cursor
                debug_assert!(dst + block <= read + i + 1);
                let src = b * block;
                stripe[dst..dst + block].copy_from_slice(&buffers.data[src..src + block]);
                buffers.lens[b] = 0;
                flushed += 1;
            }
        }
        read += m;
    }
    StripeOut {
        first_slot,
        flushed,
        counts,
        buffers,
    }
}

/// Raw-pointer wrapper so scoped threads can share disjoint regions.
#[derive(Clone, Copy)]
struct SendPtr<K>(*mut K);
unsafe impl<K> Send for SendPtr<K> {}
unsafe impl<K> Sync for SendPtr<K> {}
impl<K> SendPtr<K> {
    /// Accessor (not field) so closures capture the Sync wrapper whole.
    fn get(self) -> *mut K {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::decision_tree::DecisionTree;
    use crate::util::rng::Xoshiro256pp;

    fn check_partition(n: usize, buckets: usize, block: usize, threads: usize, seed: u64) {
        let mut rng = Xoshiro256pp::new(seed);
        let mut data: Vec<u64> = (0..n).map(|_| rng.next_below(1 << 40)).collect();
        let mut sample: Vec<u64> = if n == 0 {
            vec![0, 1, 2, 3]
        } else {
            (0..1024.min(n))
                .map(|_| data[rng.next_below(n as u64) as usize])
                .collect()
        };
        sample.sort_unstable();
        let tree = DecisionTree::from_sorted_sample(&sample, buckets);
        let mut expect = data.clone();
        expect.sort_unstable();
        let res = partition(&mut data, &tree, block, threads);
        // 1. boundaries cover the array
        assert_eq!(res.boundaries[0], 0);
        assert_eq!(*res.boundaries.last().unwrap(), n);
        // 2. every key is in the bucket the classifier assigns
        for b in 0..tree.num_buckets() {
            for &k in &data[res.boundaries[b]..res.boundaries[b + 1]] {
                assert_eq!(tree.classify(k), b, "key {k} in wrong bucket {b}");
            }
        }
        // 3. it is a permutation of the input
        let mut got = data.clone();
        got.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    fn sequential_various_shapes() {
        for &(n, buckets, block) in &[
            (0usize, 8usize, 16usize),
            (1, 8, 16),
            (15, 8, 16),
            (16, 8, 16),
            (1000, 8, 16),
            (1024, 16, 64),
            (10_000, 64, 128),
            (10_001, 64, 128),
            (4096, 256, 32),
        ] {
            check_partition(n, buckets, block, 1, 42 + n as u64);
        }
    }

    #[test]
    fn parallel_various_shapes() {
        for &(n, threads) in &[(1000usize, 2usize), (10_000, 4), (100_000, 8), (100_001, 3)] {
            check_partition(n, 64, 128, threads, 7 + threads as u64);
        }
    }

    #[test]
    fn duplicate_heavy_with_equality_buckets() {
        let mut rng = Xoshiro256pp::new(5);
        let n = 50_000;
        let mut data: Vec<u64> = (0..n).map(|_| rng.next_below(4)).collect();
        let mut sample: Vec<u64> = (0..512).map(|_| data[rng.next_below(n as u64) as usize]).collect();
        sample.sort_unstable();
        let tree = DecisionTree::from_sorted_sample(&sample, 16);
        assert!(tree.equality_buckets_enabled());
        let mut expect = data.clone();
        expect.sort_unstable();
        let res = partition(&mut data, &tree, 64, 4);
        for b in 0..tree.num_buckets() {
            let seg = &data[res.boundaries[b]..res.boundaries[b + 1]];
            if tree.is_equality_bucket(b) && !seg.is_empty() {
                assert!(seg.iter().all(|&k| k == seg[0]), "equality bucket not uniform");
            }
        }
        let mut got = data;
        got.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    fn all_equal_input() {
        let mut data = vec![9u64; 10_000];
        let sample = vec![9u64; 128];
        let tree = DecisionTree::from_sorted_sample(&sample, 8);
        let res = partition(&mut data, &tree, 32, 4);
        assert_eq!(*res.boundaries.last().unwrap(), 10_000);
        assert!(data.iter().all(|&k| k == 9));
    }

    #[test]
    fn block_bigger_than_input() {
        check_partition(50, 8, 256, 2, 99);
    }

    #[test]
    fn f64_partition() {
        let mut rng = Xoshiro256pp::new(17);
        let n = 20_000;
        let mut data: Vec<f64> = (0..n).map(|_| rng.normal() * 1e3).collect();
        let mut sample: Vec<f64> = (0..512)
            .map(|_| data[rng.next_below(n as u64) as usize])
            .collect();
        sample.sort_unstable_by(f64::total_cmp);
        let tree = DecisionTree::from_sorted_sample(&sample, 32);
        let mut expect: Vec<u64> = data.iter().map(|x| x.to_bits()).collect();
        expect.sort_unstable();
        let res = partition(&mut data, &tree, 128, 4);
        for b in 0..tree.num_buckets() {
            for &k in &data[res.boundaries[b]..res.boundaries[b + 1]] {
                assert_eq!(tree.classify(k), b);
            }
        }
        let mut got: Vec<u64> = data.iter().map(|x| x.to_bits()).collect();
        got.sort_unstable();
        assert_eq!(got, expect);
    }
}
