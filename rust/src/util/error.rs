//! Minimal error-context substrate (the `anyhow` subset the crate uses;
//! no external dependencies are available offline): an [`Error`] carrying
//! a context chain, [`Result`], the [`Context`] extension for `Result` and
//! `Option`, and the `anyhow!` / `bail!` macros (exported at crate root).
//!
//! `{e}` prints the outermost context, `{e:#}` the full chain
//! (`outer: ...: root cause`), matching how the callers format errors.

use std::fmt;

/// Context-chained error. Deliberately does **not** implement
/// `std::error::Error`, so the blanket `From<E: std::error::Error>`
/// conversion below stays coherent (the same trick `anyhow` uses).
pub struct Error {
    /// Outermost context first, root cause last.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error {
            chain: vec![msg.to_string()],
        }
    }

    fn wrap(mut self, context: String) -> Error {
        self.chain.insert(0, context);
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            writeln!(f, "\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                writeln!(f, "    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result alias over the context-chained [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(|| ...)` on `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error (or `None`) with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (crate-root export).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an [`Error`] from a format string (crate-root export).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).with_context(|| "reading manifest".to_string());
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let r: Result<u32> = None.context("missing field");
        assert_eq!(format!("{:#}", r.unwrap_err()), "missing field");
        let r: Result<u32> = Some(7).context("unused");
        assert_eq!(r.unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("bad {}", 42);
        assert_eq!(format!("{e}"), "bad 42");
        fn f() -> Result<()> {
            bail!("nope: {}", "reason")
        }
        assert_eq!(format!("{:#}", f().unwrap_err()), "nope: reason");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
