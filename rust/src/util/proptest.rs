//! Hand-rolled property-test harness (substrate S11; the `proptest` crate
//! is unavailable offline).
//!
//! Model: a property is a function `(&mut Xoshiro256pp, usize) -> Result<(),
//! String>` taking a seeded generator and a *size*. The runner sweeps
//! `iters` random (seed, size) pairs biased toward boundary sizes; on
//! failure it shrinks the size by bisection to find a minimal failing size
//! for the same seed, then panics with a reproducible report
//! (`AIPSO_PROP_SEED=<seed> size=<n>`).

use crate::util::rng::Xoshiro256pp;

/// Runner configuration for [`check_sized`].
pub struct PropConfig {
    /// Number of random (seed, size) pairs to try.
    pub iters: usize,
    /// Largest generated size.
    pub max_size: usize,
    /// Base PRNG seed (`AIPSO_PROP_SEED` overrides, for reproductions).
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Base seed overridable for reproducing failures.
        let base_seed = std::env::var("AIPSO_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xA1B5_0001);
        PropConfig {
            iters: 32,
            max_size: 1 << 14,
            base_seed,
        }
    }
}

impl PropConfig {
    /// Default config with an explicit iteration count.
    pub fn with_iters(iters: usize) -> Self {
        PropConfig {
            iters,
            ..Default::default()
        }
    }

    /// Default config with explicit iteration count and size cap.
    pub fn with_max_size(iters: usize, max_size: usize) -> Self {
        PropConfig {
            iters,
            max_size,
            ..Default::default()
        }
    }
}

/// Run a sized property; panic with a minimal reproduction on failure.
pub fn check_sized<F>(name: &str, cfg: PropConfig, prop: F)
where
    F: Fn(&mut Xoshiro256pp, usize) -> Result<(), String>,
{
    for it in 0..cfg.iters {
        let seed = cfg
            .base_seed
            .wrapping_add(it as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // Bias sizes toward interesting extremes: 0, 1, tiny, then random.
        let size = match it % 8 {
            0 => 0,
            1 => 1,
            2 => 2,
            3 => 17,
            _ => {
                let mut r = Xoshiro256pp::new(seed ^ 0x51DE_D00D);
                r.next_below(cfg.max_size as u64 + 1) as usize
            }
        };
        let mut rng = Xoshiro256pp::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            let minimal = shrink(&prop, seed, size);
            panic!(
                "property '{name}' failed: {msg}\n  reproduce with AIPSO_PROP_SEED={} size={} (minimal size {})",
                cfg.base_seed, size, minimal
            );
        }
    }
}

/// Convenience: default config.
pub fn check<F>(name: &str, prop: F)
where
    F: Fn(&mut Xoshiro256pp, usize) -> Result<(), String>,
{
    check_sized(name, PropConfig::default(), prop);
}

fn shrink<F>(prop: &F, seed: u64, failing: usize) -> usize
where
    F: Fn(&mut Xoshiro256pp, usize) -> Result<(), String>,
{
    let mut lo = 0usize;
    let mut hi = failing;
    // Bisect to the smallest failing size for this seed (monotone-ish
    // assumption; good enough for diagnostics).
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        let mut rng = Xoshiro256pp::new(seed);
        if prop(&mut rng, mid).is_err() {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    hi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_sized("tautology", PropConfig::with_iters(16), |_rng, _n| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_repro() {
        check_sized("always-fails", PropConfig::with_iters(4), |_rng, _n| {
            Err("nope".into())
        });
    }

    #[test]
    fn shrink_finds_threshold() {
        // Fails for size >= 100; shrink should land exactly on 100.
        let prop = |_: &mut Xoshiro256pp, n: usize| {
            if n >= 100 {
                Err("too big".into())
            } else {
                Ok(())
            }
        };
        assert_eq!(shrink(&prop, 1, 5000), 100);
    }

    #[test]
    fn sizes_cover_extremes() {
        use std::cell::RefCell;
        let seen = RefCell::new(Vec::new());
        check_sized(
            "observe",
            PropConfig::with_max_size(16, 64),
            |_rng, n| {
                seen.borrow_mut().push(n);
                Ok(())
            },
        );
        let seen = seen.into_inner();
        assert!(seen.contains(&0));
        assert!(seen.contains(&1));
        assert!(seen.iter().any(|&n| n > 2));
    }
}
