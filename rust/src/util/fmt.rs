//! Formatting helpers for CLI and bench reports.

/// Human-readable key count: 2_000_000 -> "2.0M".
pub fn keys(n: usize) -> String {
    if n >= 1_000_000_000 {
        format!("{:.1}G", n as f64 / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.1}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else {
        n.to_string()
    }
}

/// Throughput in keys/second: 123_456_789.0 -> "123.5M keys/s".
pub fn rate(keys_per_sec: f64) -> String {
    if keys_per_sec >= 1e9 {
        format!("{:.2}G keys/s", keys_per_sec / 1e9)
    } else if keys_per_sec >= 1e6 {
        format!("{:.2}M keys/s", keys_per_sec / 1e6)
    } else if keys_per_sec >= 1e3 {
        format!("{:.2}K keys/s", keys_per_sec / 1e3)
    } else {
        format!("{keys_per_sec:.2} keys/s")
    }
}

/// Seconds with adaptive unit.
pub fn secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Render rows as a GitHub-flavored markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (c, w) in cells.iter().zip(widths) {
            line.push_str(&format!(" {c:<w$} |"));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push('|');
    for w in &widths {
        out.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_counts() {
        assert_eq!(keys(999), "999");
        assert_eq!(keys(2_000_000), "2.0M");
        assert_eq!(keys(1_500), "1.5K");
        assert_eq!(keys(3_000_000_000), "3.0G");
    }

    #[test]
    fn rates() {
        assert_eq!(rate(123_456_789.0), "123.46M keys/s");
        assert!(rate(999.0).ends_with("keys/s"));
    }

    #[test]
    fn table_shape() {
        let t = markdown_table(
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["33".into(), "4".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[1].starts_with("|-"));
        assert!(lines[0].contains("bb"));
    }

    #[test]
    fn secs_units() {
        assert_eq!(secs(2.5), "2.500s");
        assert_eq!(secs(0.0025), "2.500ms");
        assert!(secs(0.0000025).ends_with("us"));
    }
}
