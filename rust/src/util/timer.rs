//! Timing utilities + the phase profiler used by the perf pass.
//!
//! The phase profiler is the hand-rolled replacement for the flamegraph
//! workflow (no external profiler crates offline): every engine brackets
//! its major phases with [`phase_scope`]; the bench harness reads the
//! accumulated per-phase nanoseconds to locate bottlenecks
//! (EXPERIMENTS.md §Perf). Overhead when disabled: one relaxed atomic load.
//!
//! [`phase_scope`] also bridges into the observability layer: while
//! `obs` tracing is enabled, each bracketed region additionally records
//! an `obs::trace` span under the in-memory taxonomy
//! (sampling→`sample`, model-train→`train`, classification /
//! block-permutation / cleanup→`partition`, base-case→`sort`), so
//! in-memory engine phases appear in the same `JobTelemetry` trace tree
//! as the external pipeline's.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

/// Major phases of the partitioning engines (IPS⁴o §3, LearnedSort §2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Drawing the splitter / training sample.
    Sampling = 0,
    /// Fitting the RMI (or building the splitter tree).
    ModelTrain = 1,
    /// The classify-into-blocks sweep.
    Classification = 2,
    /// The in-place block permutation.
    BlockPermutation = 3,
    /// Partition cleanup (block tails).
    Cleanup = 4,
    /// Base-case sorting.
    BaseCase = 5,
    /// Task-pool queue management.
    Scheduling = 6,
    /// Everything unbracketed.
    Other = 7,
}

/// Number of profiled phases.
pub const NUM_PHASES: usize = 8;

/// Display names, indexed by `Phase as usize`.
pub const PHASE_NAMES: [&str; NUM_PHASES] = [
    "sampling",
    "model-train",
    "classification",
    "block-permutation",
    "cleanup",
    "base-case",
    "scheduling",
    "other",
];

static ENABLED: AtomicBool = AtomicBool::new(false);
static PHASE_NS: [AtomicU64; NUM_PHASES] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

/// Turn the phase profiler on/off (benches enable it; hot paths see one
/// relaxed load when off).
pub fn set_phase_profiling(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether the phase profiler is currently on.
pub fn phase_profiling_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Zero all accumulated phase counters.
pub fn reset_phases() {
    for c in &PHASE_NS {
        c.store(0, Ordering::Relaxed);
    }
}

/// Snapshot of accumulated nanoseconds per phase.
pub fn phase_snapshot() -> [u64; NUM_PHASES] {
    let mut out = [0u64; NUM_PHASES];
    for (o, c) in out.iter_mut().zip(PHASE_NS.iter()) {
        *o = c.load(Ordering::Relaxed);
    }
    out
}

impl Phase {
    /// Span name of this phase in the observability taxonomy, or `None`
    /// for phases the trace tree does not surface (scheduling, other).
    pub const fn obs_span(self) -> Option<&'static str> {
        match self {
            Phase::Sampling => Some(crate::obs::S_SAMPLE),
            Phase::ModelTrain => Some(crate::obs::S_TRAIN),
            Phase::Classification | Phase::BlockPermutation | Phase::Cleanup => {
                Some(crate::obs::S_PARTITION)
            }
            Phase::BaseCase => Some(crate::obs::S_SORT),
            Phase::Scheduling | Phase::Other => None,
        }
    }
}

/// RAII guard accumulating wall time into a phase counter (and, while
/// obs tracing is on, recording the region as a trace span).
pub struct PhaseScope {
    phase: Phase,
    start: Option<Instant>,
    // dropped with the struct, closing the span at scope exit
    _span: Option<crate::obs::trace::Span>,
}

/// Bracket a region with a phase label. No-op (two relaxed atomic loads)
/// when both the profiler and obs tracing are disabled.
#[inline]
pub fn phase_scope(phase: Phase) -> PhaseScope {
    let start = if phase_profiling_enabled() {
        Some(Instant::now())
    } else {
        None
    };
    let _span = if crate::obs::enabled() {
        phase.obs_span().map(crate::obs::trace::span)
    } else {
        None
    };
    PhaseScope {
        phase,
        start,
        _span,
    }
}

impl Drop for PhaseScope {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            let ns = start.elapsed().as_nanos() as u64;
            PHASE_NS[self.phase as usize].fetch_add(ns, Ordering::Relaxed);
        }
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Render a phase snapshot as a short report (used by `aipso bench -v`).
pub fn phase_report(snap: &[u64; NUM_PHASES]) -> String {
    let total: u64 = snap.iter().sum();
    let mut s = String::new();
    for (name, &ns) in PHASE_NAMES.iter().zip(snap.iter()) {
        if ns > 0 {
            let pct = if total > 0 {
                100.0 * ns as f64 / total as f64
            } else {
                0.0
            };
            s.push_str(&format!(
                "  {:>18}: {:>10.3} ms ({:>5.1}%)\n",
                name,
                ns as f64 / 1e6,
                pct
            ));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_scope_accumulates_nothing() {
        set_phase_profiling(false);
        reset_phases();
        {
            let _g = phase_scope(Phase::Sampling);
        }
        assert_eq!(phase_snapshot()[Phase::Sampling as usize], 0);
    }

    #[test]
    fn enabled_scope_accumulates() {
        set_phase_profiling(true);
        reset_phases();
        {
            let _g = phase_scope(Phase::Cleanup);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let snap = phase_snapshot();
        set_phase_profiling(false);
        assert!(snap[Phase::Cleanup as usize] >= 1_000_000);
        let rep = phase_report(&snap);
        assert!(rep.contains("cleanup"));
    }

    #[test]
    fn phase_scope_bridges_into_obs_spans() {
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        crate::obs::trace::reset();
        {
            let _g = phase_scope(Phase::ModelTrain);
        }
        {
            let _g = phase_scope(Phase::Scheduling); // unmapped: no span
        }
        crate::obs::set_enabled(false);
        let spans = crate::obs::trace::snapshot();
        assert!(spans.iter().any(|s| s.name == crate::obs::S_TRAIN));
        assert!(spans.iter().all(|s| s.name != "scheduling"));
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
