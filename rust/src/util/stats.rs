//! Small statistics helpers used by the bench harness and tests.

use crate::key::SortKey;

/// Order-independent digest of a key multiset: `(count, wrapping sum,
/// wrapping sum of mixed bits)`. Two slices have equal digests iff (with
/// overwhelming probability) they are permutations of each other — the
/// "sorting didn't lose or invent keys" check used across the test suite.
pub fn multiset_digest<K: SortKey>(keys: &[K]) -> (usize, u64, u64) {
    let mut sum = 0u64;
    let mut mix = 0u64;
    for k in keys {
        let b = k.to_bits_ordered();
        sum = sum.wrapping_add(b);
        mix = mix.wrapping_add(b.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17));
    }
    (keys.len(), sum, mix)
}

/// Arithmetic mean. Empty input returns 0.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator). < 2 samples returns 0.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median (50th percentile, linear interpolation).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Smallest value (`inf` for empty input).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Largest value (`-inf` for empty input).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Count of inversions in adjacent positions (sortedness diagnostic).
pub fn adjacent_inversions<T: PartialOrd>(xs: &[T]) -> usize {
    xs.windows(2).filter(|w| w[0] > w[1]).count()
}

/// Shannon entropy (bits) of a histogram of counts — used by dataset
/// diagnostics to verify duplicate-heaviness (low entropy = many dups).
pub fn entropy_bits(counts: &[usize]) -> f64 {
    let total: usize = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &c in counts {
        if c > 0 {
            let p = c as f64 / total as f64;
            h -= p * p.log2();
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138).abs() < 0.01);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn inversions() {
        assert_eq!(adjacent_inversions(&[1, 2, 3]), 0);
        assert_eq!(adjacent_inversions(&[3, 2, 1]), 2);
    }

    #[test]
    fn entropy() {
        assert_eq!(entropy_bits(&[10, 0, 0]), 0.0);
        assert!((entropy_bits(&[5, 5]) - 1.0).abs() < 1e-12);
        assert!((entropy_bits(&[1, 1, 1, 1]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        assert_eq!(min(&[3.0, 1.0, 2.0]), 1.0);
        assert_eq!(max(&[3.0, 1.0, 2.0]), 3.0);
    }
}
