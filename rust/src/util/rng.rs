//! PRNG + distribution samplers (substrate S1).
//!
//! The paper's generators use the C++ `<random>` library; no `rand` crate is
//! available offline, so this module implements the generators from scratch:
//!
//! * [`SplitMix64`] — seeding / stream splitting (Steele et al.).
//! * [`Xoshiro256pp`] — the main generator (Blackman & Vigna, xoshiro256++).
//! * Samplers for every distribution in the paper's synthetic suite:
//!   uniform, normal (Box–Muller), log-normal, exponential, chi-squared,
//!   Gaussian mixture, and Zipf (Hörmann's rejection-inversion, the same
//!   scheme used by `std::discrete`-free C++ benchmarks).

/// SplitMix64: fast, full-period 2^64 stream; used to expand seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed the stream.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 (the construction recommended by the authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // All-zero state is invalid; SplitMix64 can only produce it with
        // negligible probability, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Xoshiro256pp { s }
    }

    /// An independent stream for worker `i` (jump-free stream splitting:
    /// reseed through SplitMix64 with a mixed seed).
    pub fn stream(seed: u64, i: u64) -> Self {
        Xoshiro256pp::new(seed ^ (i.wrapping_mul(0xA076_1D64_78BD_642F)).rotate_left(17))
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) — Lemire's multiply-shift with
    /// rejection, unbiased.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in [a, b).
    #[inline]
    pub fn uniform(&mut self, a: f64, b: f64) -> f64 {
        a + (b - a) * self.next_f64()
    }

    /// Standard normal via Box–Muller (one value per call; cache omitted to
    /// keep the generator state deterministic per call count).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 0.0 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Normal with explicit mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Log-normal: `exp(N(mu, sigma))`.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_with(mu, sigma).exp()
    }

    /// Exponential with rate `lambda` via inversion.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 0.0 {
                return -u.ln() / lambda;
            }
        }
    }

    /// Chi-squared with k degrees of freedom = sum of k squared standard
    /// normals (exact definition; k is small in the paper, k = 4).
    pub fn chi_squared(&mut self, k: u32) -> f64 {
        let mut acc = 0.0;
        for _ in 0..k {
            let z = self.normal();
            acc += z * z;
        }
        acc
    }

    /// Pareto(scale=1, shape=alpha) via inversion.
    #[inline]
    pub fn pareto(&mut self, alpha: f64) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 0.0 {
                return u.powf(-1.0 / alpha);
            }
        }
    }

    /// Poisson via inversion (small means) or PTRS would be overkill here;
    /// used by the timestamp simulators with mean < 64.
    pub fn poisson(&mut self, mean: f64) -> u64 {
        debug_assert!(mean >= 0.0 && mean < 700.0);
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l || k > 10_000 {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` elements without replacement into `out` (reservoir).
    pub fn reservoir_sample<T: Copy>(&mut self, xs: &[T], k: usize, out: &mut Vec<T>) {
        out.clear();
        if k == 0 || xs.is_empty() {
            return;
        }
        let k = k.min(xs.len());
        out.extend_from_slice(&xs[..k]);
        for i in k..xs.len() {
            let j = self.next_below((i + 1) as u64) as usize;
            if j < k {
                out[j] = xs[i];
            }
        }
    }
}

/// Zipf(s) sampler over {1, …, n} using Hörmann & Derflinger's
/// rejection-inversion — O(1) per sample for any exponent s ≠ 1.
/// The paper uses s = 0.75 ("Zipf" synthetic dataset).
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    s: f64,
    h_x1: f64,
    dist: f64,
}

impl Zipf {
    /// Sampler over `{1, …, n}` with exponent `s` (s = 1 unsupported).
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1);
        assert!(s >= 0.0 && (s - 1.0).abs() > 1e-12, "s=1 not supported");
        let h = |x: f64| -> f64 { Self::h_integral(x, s) };
        let h_x1 = h(1.5) - 1.0;
        let h_n = h(n as f64 + 0.5);
        Zipf {
            n,
            s,
            h_x1,
            dist: h_n - h_x1,
        }
    }

    /// H(x) = ((x)^(1-s) - 1) / (1 - s), the integral of x^-s.
    #[inline]
    fn h_integral(x: f64, s: f64) -> f64 {
        let log_x = x.ln();
        helper_expm1((1.0 - s) * log_x) / (1.0 - s)
    }

    #[inline]
    fn h_integral_inverse(&self, x: f64) -> f64 {
        let t = (x * (1.0 - self.s)).max(-1.0);
        (helper_log1p(t) / (1.0 - self.s)).exp()
    }

    /// Draw one Zipf-distributed rank.
    pub fn sample(&self, rng: &mut Xoshiro256pp) -> u64 {
        loop {
            let u = self.h_x1 + rng.next_f64() * self.dist;
            let x = self.h_integral_inverse(u);
            let k = x.clamp(1.0, self.n as f64).round() as u64;
            let kf = k as f64;
            // Acceptance: u >= H(k + 0.5) - k^-s  (Hörmann's condition)
            if u >= Self::h_integral(kf + 0.5, self.s) - (-self.s * kf.ln()).exp() {
                return k;
            }
        }
    }
}

#[inline]
fn helper_expm1(x: f64) -> f64 {
    x.exp_m1()
}

#[inline]
fn helper_log1p(x: f64) -> f64 {
    x.ln_1p()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_streams_differ() {
        let mut a = Xoshiro256pp::stream(7, 0);
        let mut b = Xoshiro256pp::stream(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = Xoshiro256pp::new(42);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_unbiased_range() {
        let mut r = Xoshiro256pp::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::new(9);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Xoshiro256pp::new(11);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn chi_squared_mean_is_k() {
        let mut r = Xoshiro256pp::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.chi_squared(4)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn zipf_rank1_most_frequent_and_tail_decays() {
        let mut r = Xoshiro256pp::new(17);
        let z = Zipf::new(1000, 0.75);
        let mut counts = vec![0usize; 1001];
        for _ in 0..200_000 {
            let k = z.sample(&mut r) as usize;
            assert!((1..=1000).contains(&k));
            counts[k] += 1;
        }
        assert!(counts[1] > counts[10]);
        assert!(counts[10] > counts[500]);
        // empirical ratio count(1)/count(16) ≈ 16^0.75 ≈ 8
        let ratio = counts[1] as f64 / counts[16].max(1) as f64;
        assert!(ratio > 4.0 && ratio < 16.0, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::new(23);
        let mut xs: Vec<u32> = (0..1000).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(xs, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn reservoir_sample_size_and_membership() {
        let mut r = Xoshiro256pp::new(29);
        let xs: Vec<u64> = (0..10_000).collect();
        let mut out = Vec::new();
        r.reservoir_sample(&xs, 100, &mut out);
        assert_eq!(out.len(), 100);
        assert!(out.iter().all(|x| *x < 10_000));
    }

    #[test]
    fn poisson_mean() {
        let mut r = Xoshiro256pp::new(31);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.poisson(3.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 3.5).abs() < 0.1, "mean={mean}");
    }
}
