//! Shared substrates: PRNG + distribution samplers, statistics, timers,
//! a property-test harness, error contexts, and formatting helpers.

pub mod error;
pub mod fmt;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
