//! Minimal JSON parser and serializer (no serde offline). Parsing covers
//! the full JSON value grammar (the artifact manifest's consumer);
//! [`Json::dump`] serializes values back out — the telemetry export path
//! (`obs::job_telemetry`) and the coordinator's JSON dump go through it.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (keys sorted).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element lookup (`None` for non-arrays / out of range).
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Serialize to compact JSON text. Numbers use the shortest exact
    /// `f64` form (`3`, not `3.0`); non-finite numbers (which JSON cannot
    /// represent) serialize as `null`.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Append `s` as a JSON string literal (quotes, backslash escapes, and
/// `\u00XX` for other control characters).
fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(c) => {
                    // copy raw utf-8 bytes through
                    let start = self.i;
                    let len = utf8_len(c);
                    self.i += len;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"format": "hlo-text", "n_leaves": 1024,
                "functions": {"rmi_train": {"file": "rmi_train.hlo.txt",
                "inputs": [["sample", [16384], "f64"]]}}}"#,
        )
        .unwrap();
        assert_eq!(j.get("n_leaves").unwrap().as_usize(), Some(1024));
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text"));
        let f = j.get("functions").unwrap().get("rmi_train").unwrap();
        assert_eq!(f.get("file").unwrap().as_str(), Some("rmi_train.hlo.txt"));
        let shape = f.get("inputs").unwrap().idx(0).unwrap().idx(1).unwrap();
        assert_eq!(shape.idx(0).unwrap().as_usize(), Some(16384));
    }

    #[test]
    fn parses_scalars_and_arrays() {
        assert_eq!(Json::parse("3.5").unwrap().as_f64(), Some(3.5));
        assert_eq!(Json::parse("-2e3").unwrap().as_f64(), Some(-2000.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("[1, 2]").unwrap(),
            Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])
        );
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn string_escapes() {
        assert_eq!(
            Json::parse(r#""a\n\"bA""#).unwrap().as_str(),
            Some("a\n\"bA")
        );
    }

    #[test]
    fn dump_roundtrips_values() {
        for src in [
            r#"{"a":[1,2.5,-3],"b":{"c":"x\ny","d":null},"e":true}"#,
            "[]",
            "{}",
            r#""plain""#,
            "false",
        ] {
            let v = Json::parse(src).unwrap();
            let text = v.dump();
            assert_eq!(Json::parse(&text).unwrap(), v, "roundtrip of {src}");
        }
    }

    #[test]
    fn dump_number_forms() {
        assert_eq!(Json::Num(3.0).dump(), "3", "whole floats print as ints");
        assert_eq!(Json::Num(0.25).dump(), "0.25");
        assert_eq!(Json::Num(f64::NAN).dump(), "null", "NaN is not JSON");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
    }

    #[test]
    fn dump_escapes_strings_and_sorts_keys() {
        let v = Json::parse(r#"{"z":1,"a":"q\"\\"}"#).unwrap();
        let text = v.dump();
        assert!(text.starts_with(r#"{"a":"#), "BTreeMap keys sort: {text}");
        assert!(text.contains("\\\"") && text.contains("\\\\"), "escapes: {text}");
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nope").is_err());
    }
}
