//! Counters, gauges, and fixed-bucket histograms — the numeric side of
//! the observability layer.
//!
//! A [`MetricSet`] is a self-contained registry instance: the
//! coordinator's `MetricsRegistry` owns one per service lifetime, while
//! the pipeline-level helpers ([`counter_add`], [`gauge_set`],
//! [`observe`]) write to a process-global set that
//! [`crate::obs::job_telemetry`] exports. The global helpers check
//! [`crate::obs::enabled`] first, so with observability off a call is one
//! relaxed atomic load — no lock, no allocation.
//!
//! Histograms use *fixed* bucket bounds supplied at the observe site (the
//! `*_BUCKETS` constants below): cumulative-style upper bounds plus an
//! implicit overflow bucket, with exact `count`/`sum`/`min`/`max`
//! alongside, so exports stay mergeable and schema-stable.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;

/// Bucket upper bounds for byte-volume histograms (4 KiB … 4 GiB, powers
/// of four).
pub const BYTES_BUCKETS: &[f64] = &[
    4096.0,
    16384.0,
    65536.0,
    262144.0,
    1048576.0,
    4194304.0,
    16777216.0,
    67108864.0,
    268435456.0,
    1073741824.0,
    4294967296.0,
];

/// Bucket upper bounds for error/ratio-style values in `[0, 1]` (drift
/// probe error, per-epoch learned ratio).
pub const RATIO_BUCKETS: &[f64] = &[0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 0.75, 0.9, 1.0];

/// Bucket upper bounds for shard-skew factors (1 = perfectly balanced).
pub const SKEW_BUCKETS: &[f64] = &[1.0, 1.25, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0, 16.0];

/// Bucket upper bounds for fan-in / small-count histograms.
pub const FANIN_BUCKETS: &[f64] = &[2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];

/// Bucket upper bounds for queue depths (coordinator lane, task pool).
pub const DEPTH_BUCKETS: &[f64] = &[0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 256.0];

/// One fixed-bucket histogram: `counts[i]` tallies observations `<=
/// bounds[i]` (and above `bounds[i-1]`); the final slot is the overflow
/// bucket.
#[derive(Debug, Clone)]
struct Histogram {
    bounds: &'static [f64],
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    fn new(bounds: &'static [f64]) -> Histogram {
        Histogram {
            bounds,
            counts: vec![0; bounds.len() + 1],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn observe(&mut self, v: f64) {
        let slot = self.bounds.partition_point(|&b| b < v);
        self.counts[slot] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }
}

/// Read-only copy of one histogram's state, as exported by
/// [`MetricSet::snapshot`].
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    /// Bucket upper bounds (the overflow bucket is implicit).
    pub bounds: Vec<f64>,
    /// Per-bucket tallies (`bounds.len() + 1` slots, last = overflow).
    pub counts: Vec<u64>,
    /// Total observations.
    pub count: u64,
    /// Sum of all observed values.
    pub sum: f64,
    /// Smallest observed value.
    pub min: f64,
    /// Largest observed value.
    pub max: f64,
}

impl HistogramSnapshot {
    /// Serialize for the telemetry document: `{count, sum, min, max,
    /// buckets: [{le, count}...]}` with `le: null` on the overflow
    /// bucket.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(self.count as f64));
        m.insert("sum".to_string(), Json::Num(self.sum));
        m.insert("min".to_string(), Json::Num(self.min));
        m.insert("max".to_string(), Json::Num(self.max));
        let buckets = self
            .counts
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let mut b = BTreeMap::new();
                let le = match self.bounds.get(i) {
                    Some(&bound) => Json::Num(bound),
                    None => Json::Null, // overflow bucket
                };
                b.insert("le".to_string(), le);
                b.insert("count".to_string(), Json::Num(c as f64));
                Json::Obj(b)
            })
            .collect();
        m.insert("buckets".to_string(), Json::Arr(buckets));
        Json::Obj(m)
    }
}

/// Read-only copy of a whole [`MetricSet`].
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Counter values by name.
    pub counters: BTreeMap<String, u64>,
    /// Gauge values by name.
    pub gauges: BTreeMap<String, f64>,
    /// Histogram states by name.
    pub hists: BTreeMap<String, HistogramSnapshot>,
}

impl MetricsSnapshot {
    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    /// Serialize for the telemetry document:
    /// `{counters: {..}, gauges: {..}, histograms: {..}}`.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "counters".to_string(),
            Json::Obj(
                self.counters
                    .iter()
                    .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                    .collect(),
            ),
        );
        m.insert(
            "gauges".to_string(),
            Json::Obj(
                self.gauges
                    .iter()
                    .map(|(k, &v)| (k.clone(), Json::Num(v)))
                    .collect(),
            ),
        );
        m.insert(
            "histograms".to_string(),
            Json::Obj(
                self.hists
                    .iter()
                    .map(|(k, v)| (k.clone(), v.to_json()))
                    .collect(),
            ),
        );
        Json::Obj(m)
    }
}

/// Inner mutable state of a [`MetricSet`].
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

/// A registry instance: thread-safe counters, gauges, and fixed-bucket
/// histograms keyed by name. `const`-constructible so a process-global
/// set costs nothing until first use.
pub struct MetricSet {
    inner: Mutex<Inner>,
}

impl MetricSet {
    /// Empty registry.
    pub const fn new() -> MetricSet {
        MetricSet {
            inner: Mutex::new(Inner {
                counters: BTreeMap::new(),
                gauges: BTreeMap::new(),
                hists: BTreeMap::new(),
            }),
        }
    }

    /// Add `v` to counter `name` (creating it at zero).
    pub fn add(&self, name: &str, v: u64) {
        let mut g = self.inner.lock().unwrap();
        match g.counters.get_mut(name) {
            Some(c) => *c += v,
            None => {
                g.counters.insert(name.to_string(), v);
            }
        }
    }

    /// Set gauge `name` to `v`.
    pub fn set_gauge(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        match g.gauges.get_mut(name) {
            Some(s) => *s = v,
            None => {
                g.gauges.insert(name.to_string(), v);
            }
        }
    }

    /// Record `v` into histogram `name`, creating it with `bounds` on
    /// first use (later calls keep the original bounds — fixed buckets).
    pub fn observe(&self, name: &str, bounds: &'static [f64], v: f64) {
        let mut g = self.inner.lock().unwrap();
        match g.hists.get_mut(name) {
            Some(h) => h.observe(v),
            None => {
                let mut h = Histogram::new(bounds);
                h.observe(v);
                g.hists.insert(name.to_string(), h);
            }
        }
    }

    /// Read one counter (0 when never written).
    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Copy out the whole registry.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        MetricsSnapshot {
            counters: g.counters.clone(),
            gauges: g.gauges.clone(),
            hists: g
                .hists
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        HistogramSnapshot {
                            bounds: h.bounds.to_vec(),
                            counts: h.counts.clone(),
                            count: h.count,
                            sum: h.sum,
                            min: h.min,
                            max: h.max,
                        },
                    )
                })
                .collect(),
        }
    }

    /// Clear every counter, gauge, and histogram.
    pub fn reset(&self) {
        let mut g = self.inner.lock().unwrap();
        g.counters.clear();
        g.gauges.clear();
        g.hists.clear();
    }
}

impl Default for MetricSet {
    fn default() -> Self {
        MetricSet::new()
    }
}

impl std::fmt::Debug for MetricSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let g = self.inner.lock().unwrap();
        f.debug_struct("MetricSet")
            .field("counters", &g.counters.len())
            .field("gauges", &g.gauges.len())
            .field("histograms", &g.hists.len())
            .finish()
    }
}

/// The process-global registry the pipeline helpers write to.
static GLOBAL: MetricSet = MetricSet::new();

/// The process-global registry (for direct reads in tests/tools).
pub fn global() -> &'static MetricSet {
    &GLOBAL
}

/// Add `v` to global counter `name` — no-op while observability is off.
pub fn counter_add(name: &str, v: u64) {
    if crate::obs::enabled() {
        GLOBAL.add(name, v);
    }
}

/// Set global gauge `name` — no-op while observability is off.
pub fn gauge_set(name: &str, v: f64) {
    if crate::obs::enabled() {
        GLOBAL.set_gauge(name, v);
    }
}

/// Record `v` into global histogram `name` — no-op while observability
/// is off.
pub fn observe(name: &str, bounds: &'static [f64], v: f64) {
    if crate::obs::enabled() {
        GLOBAL.observe(name, bounds, v);
    }
}

/// Snapshot the global registry (works regardless of the enabled flag).
pub fn snapshot() -> MetricsSnapshot {
    GLOBAL.snapshot()
}

/// Clear the global registry.
pub fn reset() {
    GLOBAL.reset();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_and_hists_roundtrip() {
        let set = MetricSet::new();
        set.add("obs.test.jobs", 2);
        set.add("obs.test.jobs", 3);
        set.set_gauge("obs.test.depth", 7.0);
        set.set_gauge("obs.test.depth", 4.0);
        set.observe("obs.test.skew", SKEW_BUCKETS, 1.1);
        set.observe("obs.test.skew", SKEW_BUCKETS, 3.5);
        set.observe("obs.test.skew", SKEW_BUCKETS, 100.0); // overflow
        assert_eq!(set.counter("obs.test.jobs"), 5);
        let snap = set.snapshot();
        assert_eq!(snap.gauges["obs.test.depth"], 4.0);
        let h = &snap.hists["obs.test.skew"];
        assert_eq!(h.count, 3);
        assert_eq!(h.min, 1.1);
        assert_eq!(h.max, 100.0);
        assert_eq!(*h.counts.last().unwrap(), 1, "100 lands in overflow");
        // 1.1 -> first bound >= 1.1 is 1.25 (index 1); 3.5 -> 4.0 (index 5)
        assert_eq!(h.counts[1], 1);
        assert_eq!(h.counts[5], 1);
        assert_eq!(h.counts.len(), SKEW_BUCKETS.len() + 1);
    }

    #[test]
    fn bucket_edges_are_inclusive_upper_bounds() {
        let set = MetricSet::new();
        set.observe("obs.test.edge", FANIN_BUCKETS, 2.0);
        set.observe("obs.test.edge", FANIN_BUCKETS, 2.0001);
        let h = &set.snapshot().hists["obs.test.edge"];
        assert_eq!(h.counts[0], 1, "v == bound stays in its bucket");
        assert_eq!(h.counts[1], 1, "v just above moves up");
    }

    #[test]
    fn disabled_global_helpers_record_nothing() {
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(false);
        reset();
        counter_add("obs.test.off", 1);
        gauge_set("obs.test.off.g", 1.0);
        observe("obs.test.off.h", RATIO_BUCKETS, 0.5);
        let snap = snapshot();
        assert!(!snap.counters.contains_key("obs.test.off"));
        assert!(!snap.gauges.contains_key("obs.test.off.g"));
        assert!(!snap.hists.contains_key("obs.test.off.h"));
    }

    #[test]
    fn enabled_global_helpers_record() {
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        reset();
        counter_add("obs.test.on", 2);
        observe("obs.test.on.h", DEPTH_BUCKETS, 3.0);
        crate::obs::set_enabled(false);
        let snap = snapshot();
        assert_eq!(snap.counters["obs.test.on"], 2);
        assert_eq!(snap.hists["obs.test.on.h"].count, 1);
    }

    #[test]
    fn snapshot_serializes_schema_shape() {
        let set = MetricSet::new();
        set.add("c", 1);
        set.observe("h", RATIO_BUCKETS, 0.03);
        let j = set.snapshot().to_json();
        assert!(j.get("counters").and_then(|c| c.get("c")).is_some());
        let h = j.get("histograms").and_then(|hs| hs.get("h")).unwrap();
        assert_eq!(h.get("count").and_then(|c| c.as_f64()), Some(1.0));
        let buckets = h.get("buckets").unwrap();
        assert_eq!(
            buckets.idx(RATIO_BUCKETS.len()).unwrap().get("le"),
            Some(&crate::util::json::Json::Null),
            "overflow bucket has null le"
        );
    }
}
