//! Scoped span tracer — the phase-level wall-clock record of one job.
//!
//! A [`Span`] is an RAII guard opened with [`span`] (or [`span_n`] when
//! the key/byte volume is known up front). While [`crate::obs::enabled`]
//! is off, opening a span is one relaxed atomic load and the guard holds
//! nothing — no allocation, no lock, no record. While on, every span
//! records its name, wall time, parent, and optional key/byte volumes
//! into a global buffer that [`snapshot`] drains into [`SpanData`] rows
//! and [`trace_tree`] folds into the aggregated per-phase tree the
//! telemetry export serializes.
//!
//! Parenting is per thread: a span opened while another span is open *on
//! the same thread* becomes its child; spans opened on worker threads
//! (pool tasks, pipeline stages) become roots. The tree aggregation
//! groups spans by name per nesting level, so repeated phases (one
//! `chunk-sort` per chunk) collapse into one node with a count.

use std::cell::RefCell;
use std::sync::Mutex;
use std::time::Instant;

/// One recorded span, as drained by [`snapshot`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanData {
    /// Phase name (one of the taxonomy in [`crate::obs::KNOWN_SPANS`],
    /// or a test-local name).
    pub name: &'static str,
    /// Index of the parent span in the same snapshot (`None` = root).
    pub parent: Option<u32>,
    /// Start time in nanoseconds since the trace epoch (first span after
    /// the last [`reset`]).
    pub start_ns: u64,
    /// Wall-clock duration in nanoseconds.
    pub dur_ns: u64,
    /// Keys processed under this span (0 when not applicable).
    pub keys: u64,
    /// Bytes read or written under this span (0 when not applicable).
    pub bytes: u64,
}

/// Global trace buffer. `generation` invalidates open guards and
/// thread-local parent stacks across [`reset`] calls, so a guard that
/// outlives a reset can never patch an unrelated record.
struct TraceState {
    spans: Vec<SpanData>,
    epoch: Option<Instant>,
    generation: u64,
}

static STATE: Mutex<TraceState> = Mutex::new(TraceState {
    spans: Vec::new(),
    epoch: None,
    generation: 0,
});

thread_local! {
    /// Stack of `(generation, span index)` for spans open on this thread.
    static PARENTS: RefCell<Vec<(u64, u32)>> = const { RefCell::new(Vec::new()) };
}

/// Scoped span guard: records its duration (and any volumes set on it)
/// when dropped. Inert when tracing was disabled at open time.
pub struct Span {
    inner: Option<OpenSpan>,
}

struct OpenSpan {
    generation: u64,
    id: u32,
    start: Instant,
    keys: u64,
    bytes: u64,
}

impl Span {
    /// Attribute `keys` processed keys to this span.
    pub fn set_keys(&mut self, keys: u64) {
        if let Some(s) = &mut self.inner {
            s.keys = keys;
        }
    }

    /// Attribute `bytes` of IO to this span.
    pub fn set_bytes(&mut self, bytes: u64) {
        if let Some(s) = &mut self.inner {
            s.bytes = bytes;
        }
    }

    /// Add to this span's key count (for incremental producers).
    pub fn add_keys(&mut self, keys: u64) {
        if let Some(s) = &mut self.inner {
            s.keys += keys;
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(open) = self.inner.take() else {
            return;
        };
        let dur_ns = open.start.elapsed().as_nanos() as u64;
        {
            let mut st = STATE.lock().unwrap();
            if st.generation == open.generation {
                if let Some(rec) = st.spans.get_mut(open.id as usize) {
                    rec.dur_ns = dur_ns;
                    rec.keys = open.keys;
                    rec.bytes = open.bytes;
                }
            }
        }
        PARENTS.with(|p| {
            let mut stack = p.borrow_mut();
            if stack.last() == Some(&(open.generation, open.id)) {
                stack.pop();
            } else {
                // reset happened under an open guard: drop stale entries
                stack.retain(|&(g, i)| (g, i) != (open.generation, open.id));
            }
        });
    }
}

/// Open a span named `name`. Near-free when tracing is disabled (one
/// relaxed atomic load; the guard is inert).
pub fn span(name: &'static str) -> Span {
    if !crate::obs::enabled() {
        return Span { inner: None };
    }
    let start = Instant::now();
    let mut st = STATE.lock().unwrap();
    let epoch = *st.epoch.get_or_insert(start);
    let generation = st.generation;
    let parent = PARENTS.with(|p| {
        let mut stack = p.borrow_mut();
        stack.retain(|&(g, _)| g == generation);
        stack.last().map(|&(_, id)| id)
    });
    let id = st.spans.len() as u32;
    st.spans.push(SpanData {
        name,
        parent,
        start_ns: start.duration_since(epoch).as_nanos() as u64,
        dur_ns: 0,
        keys: 0,
        bytes: 0,
    });
    drop(st);
    PARENTS.with(|p| p.borrow_mut().push((generation, id)));
    Span {
        inner: Some(OpenSpan {
            generation,
            id,
            start,
            keys: 0,
            bytes: 0,
        }),
    }
}

/// [`span`] with key/byte volumes known up front.
pub fn span_n(name: &'static str, keys: u64, bytes: u64) -> Span {
    let mut s = span(name);
    s.set_keys(keys);
    s.set_bytes(bytes);
    s
}

/// Snapshot every span recorded since the last [`reset`] (closed spans
/// carry their durations; still-open spans appear with `dur_ns == 0`).
pub fn snapshot() -> Vec<SpanData> {
    STATE.lock().unwrap().spans.clone()
}

/// Number of spans recorded since the last [`reset`].
pub fn span_count() -> usize {
    STATE.lock().unwrap().spans.len()
}

/// Clear the trace buffer and start a fresh epoch. Guards still open
/// across a reset become no-ops (they never patch the new buffer).
pub fn reset() {
    let mut st = STATE.lock().unwrap();
    st.spans.clear();
    st.epoch = None;
    st.generation += 1;
}

/// One node of the aggregated trace tree: all spans sharing a name *and*
/// a parent path fold into one node, so per-chunk phases collapse into a
/// count instead of an unbounded list.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceNode {
    /// Phase name.
    pub name: &'static str,
    /// Spans folded into this node.
    pub count: u64,
    /// Total wall-clock nanoseconds across the folded spans.
    pub total_ns: u64,
    /// Total keys attributed across the folded spans.
    pub keys: u64,
    /// Total bytes attributed across the folded spans.
    pub bytes: u64,
    /// Child phases, sorted by name.
    pub children: Vec<TraceNode>,
}

impl TraceNode {
    /// Serialize this node (recursively) for the telemetry document.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.to_string()));
        m.insert("count".to_string(), Json::Num(self.count as f64));
        m.insert("total_ns".to_string(), Json::Num(self.total_ns as f64));
        m.insert("keys".to_string(), Json::Num(self.keys as f64));
        m.insert("bytes".to_string(), Json::Num(self.bytes as f64));
        m.insert(
            "children".to_string(),
            Json::Arr(self.children.iter().map(TraceNode::to_json).collect()),
        );
        Json::Obj(m)
    }
}

/// Fold a flat span snapshot into the aggregated tree (roots sorted by
/// name, spans grouped by name at every level).
pub fn trace_tree(spans: &[SpanData]) -> Vec<TraceNode> {
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); spans.len()];
    let mut roots: Vec<usize> = Vec::new();
    for (i, s) in spans.iter().enumerate() {
        match s.parent {
            Some(p) if (p as usize) < i => children[p as usize].push(i),
            _ => roots.push(i),
        }
    }
    fold_level(spans, &roots, &children)
}

/// Group one level's span indices by name and aggregate each group.
fn fold_level(
    spans: &[SpanData],
    level: &[usize],
    children: &[Vec<usize>],
) -> Vec<TraceNode> {
    let mut by_name: std::collections::BTreeMap<&'static str, Vec<usize>> =
        std::collections::BTreeMap::new();
    for &i in level {
        by_name.entry(spans[i].name).or_default().push(i);
    }
    by_name
        .into_iter()
        .map(|(name, idxs)| {
            let mut node = TraceNode {
                name,
                count: idxs.len() as u64,
                total_ns: 0,
                keys: 0,
                bytes: 0,
                children: Vec::new(),
            };
            let mut kids: Vec<usize> = Vec::new();
            for &i in &idxs {
                node.total_ns += spans[i].dur_ns;
                node.keys += spans[i].keys;
                node.bytes += spans[i].bytes;
                kids.extend_from_slice(&children[i]);
            }
            node.children = fold_level(spans, &kids, children);
            node
        })
        .collect()
}

/// Every distinct span name in a snapshot (sorted, deduplicated).
pub fn span_names(spans: &[SpanData]) -> Vec<&'static str> {
    let mut names: Vec<&'static str> = spans.iter().map(|s| s.name).collect();
    names.sort_unstable();
    names.dedup();
    names
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        // No set_enabled here: rely on unique names instead of global
        // state, so parallel tests that enable tracing can't interfere
        // with an assertion about *these* names never being recorded.
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(false);
        {
            let mut s = span("obs-test-disabled");
            s.set_keys(10);
            s.set_bytes(20);
        }
        let recorded = snapshot()
            .iter()
            .filter(|s| s.name == "obs-test-disabled")
            .count();
        assert_eq!(recorded, 0, "disabled tracing must record no spans");
    }

    #[test]
    fn nesting_links_parents_on_one_thread() {
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        reset();
        {
            let mut outer = span("obs-test-outer");
            outer.set_keys(100);
            {
                let mut inner = span("obs-test-inner");
                inner.set_bytes(7);
            }
        }
        crate::obs::set_enabled(false);
        let spans = snapshot();
        let outer = spans
            .iter()
            .position(|s| s.name == "obs-test-outer")
            .expect("outer recorded");
        let inner = spans
            .iter()
            .find(|s| s.name == "obs-test-inner")
            .expect("inner recorded");
        assert_eq!(inner.parent, Some(outer as u32));
        assert_eq!(spans[outer].parent, None);
        assert_eq!(spans[outer].keys, 100);
        assert_eq!(inner.bytes, 7);
        let tree = trace_tree(&spans);
        let root = tree.iter().find(|n| n.name == "obs-test-outer").unwrap();
        assert_eq!(root.count, 1);
        assert_eq!(root.children.len(), 1);
        assert_eq!(root.children[0].name, "obs-test-inner");
    }

    #[test]
    fn threads_record_independent_roots() {
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        reset();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..25 {
                        let mut g = span("obs-test-thread");
                        g.set_keys(1);
                    }
                });
            }
        });
        crate::obs::set_enabled(false);
        let spans = snapshot();
        let mine: Vec<_> = spans
            .iter()
            .filter(|s| s.name == "obs-test-thread")
            .collect();
        assert_eq!(mine.len(), 100);
        assert!(mine.iter().all(|s| s.parent.is_none()), "workers are roots");
        assert_eq!(mine.iter().map(|s| s.keys).sum::<u64>(), 100);
    }

    #[test]
    fn tree_aggregates_repeated_phases() {
        // Pure aggregation — no global state involved.
        let spans = vec![
            SpanData {
                name: "job",
                parent: None,
                start_ns: 0,
                dur_ns: 100,
                keys: 0,
                bytes: 0,
            },
            SpanData {
                name: "chunk",
                parent: Some(0),
                start_ns: 1,
                dur_ns: 10,
                keys: 5,
                bytes: 40,
            },
            SpanData {
                name: "chunk",
                parent: Some(0),
                start_ns: 20,
                dur_ns: 30,
                keys: 7,
                bytes: 56,
            },
        ];
        let tree = trace_tree(&spans);
        assert_eq!(tree.len(), 1);
        let job = &tree[0];
        assert_eq!((job.name, job.count, job.total_ns), ("job", 1, 100));
        assert_eq!(job.children.len(), 1);
        let chunk = &job.children[0];
        assert_eq!(chunk.count, 2);
        assert_eq!(chunk.total_ns, 40);
        assert_eq!(chunk.keys, 12);
        assert_eq!(chunk.bytes, 96);
        assert_eq!(span_names(&spans), vec!["chunk", "job"]);
    }

    #[test]
    fn reset_orphans_open_guards_safely() {
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        reset();
        let g = span("obs-test-orphan");
        reset(); // new generation while g is still open
        let mut h = span("obs-test-fresh");
        h.set_keys(3);
        drop(h);
        drop(g); // must not patch (or corrupt) the new buffer
        crate::obs::set_enabled(false);
        let spans = snapshot();
        assert!(spans.iter().all(|s| s.name != "obs-test-orphan"));
        let fresh = spans.iter().find(|s| s.name == "obs-test-fresh").unwrap();
        assert_eq!(fresh.keys, 3);
    }
}
