//! Observability: phase-span tracing, pipeline metrics, and
//! machine-readable job telemetry.
//!
//! Always compiled, near-zero overhead when disabled: every
//! instrumentation point ([`trace::span`], [`metrics::counter_add`], …)
//! checks one process-global relaxed [`AtomicBool`] and does nothing —
//! no lock, no allocation — until [`set_enabled`]`(true)`. The pipeline
//! is threaded with spans (the taxonomy in [`KNOWN_SPANS`]) and metrics
//! (the `M_*`/`C_*` names below); [`job_telemetry`] folds both into one
//! `JobTelemetry` JSON document:
//!
//! ```json
//! {
//!   "schema": "aipso.telemetry.v1",
//!   "trace": {"spans": [{"name", "count", "total_ns", "keys", "bytes",
//!                        "children": [...]}]},
//!   "metrics": {"counters": {}, "gauges": {},
//!               "histograms": {"name": {"count", "sum", "min", "max",
//!                              "buckets": [{"le", "count"}]}}},
//!   "report": {...} | null
//! }
//! ```
//!
//! `aipso extsort --trace-json <path>` emits the document;
//! `aipso telemetry-check` (and the golden-schema test) validate it with
//! [`validate_telemetry`] — unknown span names fail, so the taxonomy
//! stays pinned.
//!
//! ```
//! use aipso::obs;
//!
//! obs::reset();
//! obs::set_enabled(true);
//! {
//!     let mut s = obs::trace::span("chunk-sort");
//!     s.set_keys(1024);
//! }
//! obs::metrics::observe(obs::M_SHARD_SKEW, obs::metrics::SKEW_BUCKETS, 1.5);
//! obs::set_enabled(false);
//! let doc = obs::job_telemetry(None);
//! assert!(obs::validate_telemetry(&doc, &["chunk-sort"], &[obs::M_SHARD_SKEW]).is_ok());
//! ```

pub mod metrics;
pub mod trace;

use std::sync::atomic::{AtomicBool, Ordering};

use crate::util::json::Json;

/// Schema identifier pinned by the golden test and checked by
/// [`validate_telemetry`].
pub const SCHEMA: &str = "aipso.telemetry.v1";

/// Whole-job root span of an external sort.
pub const S_EXTSORT: &str = "extsort";
/// One chunk read from the input (run generation).
pub const S_CHUNK_READ: &str = "chunk-read";
/// One chunk sorted (learned partition or IPS⁴o fallback).
pub const S_CHUNK_SORT: &str = "chunk-sort";
/// One sorted chunk spilled as a run.
pub const S_SPILL_WRITE: &str = "spill-write";
/// One mid-stream model retrain attempt (drift streak tripped).
pub const S_RETRAIN: &str = "retrain";
/// One k-way merge pass (intermediate or final).
pub const S_MERGE_PASS: &str = "merge-pass";
/// One range-disjoint shard of a sharded merge.
pub const S_SHARD_MERGE: &str = "shard-merge";
/// In-memory engines: pivot/splitter sampling.
pub const S_SAMPLE: &str = "sample";
/// In-memory engines: RMI/decision-tree training.
pub const S_TRAIN: &str = "train";
/// In-memory engines: classification + block permutation + cleanup.
pub const S_PARTITION: &str = "partition";
/// In-memory engines: base-case sorts.
pub const S_SORT: &str = "sort";
/// LearnedSort 2.0 fragmentation sweep (batched classify + fragment
/// flushes over the consumed prefix); nested under [`S_PARTITION`].
pub const S_FRAG_PARTITION: &str = "frag-partition";
/// LearnedSort 2.0 compaction pass (fragment-chain permutation + bucket
/// reassembly); nested under [`S_PARTITION`].
pub const S_FRAG_COMPACT: &str = "frag-compact";
/// Parallel fragmented partition: the per-thread stripe sweeps (each
/// worker classifies its stripe into a private fragment chain). Emitted
/// on the caller thread around the fork-join.
pub const S_FRAG_PAR_SWEEP: &str = "frag-par-sweep";
/// Parallel fragmented partition: the deterministic per-thread chain
/// merge, the global cycle-following slot compaction and the boundary
/// shift.
pub const S_FRAG_PAR_MERGE: &str = "frag-par-merge";
/// One positioned spill read or write executed by the IO substrate
/// (inline on the sync backend — nested under the issuing phase — or on
/// a pool worker thread, where it appears as a root).
pub const S_SPILL_IO: &str = "spill-io";

/// The complete span taxonomy. [`validate_telemetry`] rejects any other
/// name, so adding a phase means extending this list (and the docs).
pub const KNOWN_SPANS: &[&str] = &[
    S_EXTSORT,
    S_CHUNK_READ,
    S_CHUNK_SORT,
    S_SPILL_WRITE,
    S_RETRAIN,
    S_MERGE_PASS,
    S_SHARD_MERGE,
    S_SAMPLE,
    S_TRAIN,
    S_PARTITION,
    S_SORT,
    S_FRAG_PARTITION,
    S_FRAG_COMPACT,
    S_FRAG_PAR_SWEEP,
    S_FRAG_PAR_MERGE,
    S_SPILL_IO,
];

/// External-pipeline phases every multi-run `extsort` emits (retrain and
/// shard-merge are input-dependent and validated separately).
pub const BASE_EXTSORT_SPANS: &[&str] =
    &[S_CHUNK_READ, S_CHUNK_SORT, S_SPILL_WRITE, S_MERGE_PASS];

/// Histogram: encoded on-disk bytes per spilled run.
pub const M_SPILL_BYTES_ENCODED: &str = "spill.run.bytes.encoded";
/// Histogram: fixed-width (raw-equivalent) bytes per spilled run.
pub const M_SPILL_BYTES_RAW: &str = "spill.run.bytes.raw";
/// Histogram: drift-probe error (mean |F(x) − empirical CDF|) per probe.
pub const M_DRIFT_ERROR: &str = "drift.probe.error";
/// Histogram: learned-chunk fraction per model epoch.
pub const M_EPOCH_LEARNED_RATIO: &str = "epoch.learned.ratio";
/// Histogram: shard-plan skew factor (largest shard ÷ ideal).
pub const M_SHARD_SKEW: &str = "merge.shard.skew";
/// Histogram: runs per merge group (the effective fan-in).
pub const M_MERGE_FANIN: &str = "merge.fan.in";
/// Histogram: pending external jobs behind the coordinator's overlap
/// lane, sampled at every lane event.
pub const M_LANE_DEPTH: &str = "coord.lane.queue.depth";
/// Histogram: task-pool queue depth, sampled at every spawn.
pub const M_POOL_DEPTH: &str = "pool.queue.depth";
/// Counter: sharded-merge range opens served by the planner's v2 block
/// directory (O(log blocks) seek, no header walk).
pub const C_DIR_HIT: &str = "shard.dir.hit";
/// Counter: v2 range opens that re-walked block headers (no directory).
pub const C_DIR_REWALK: &str = "shard.dir.rewalk";
/// Counter: sorted runs spilled.
pub const C_SPILL_RUNS: &str = "spill.runs";
/// Counter: successful mid-stream model installs.
pub const C_RETRAINS: &str = "retrain.count";
/// Counter: merge passes executed (intermediate + final).
pub const C_MERGE_PASSES: &str = "merge.passes";
/// Counter: thread-parallel fragmented partitions executed (the
/// LearnedSort 2.0 parallel formulation; the sequential fallback for
/// degenerate splits does not count).
pub const C_FRAG_PAR: &str = "frag.par.partitions";
/// Counter: positioned spill writes executed by the IO substrate (both
/// backends; one per dispatched buffer, not per byte).
pub const C_IO_WRITES: &str = "io.writes";
/// Counter: positioned spill reads executed by the pool backend's
/// read-ahead path.
pub const C_IO_READS: &str = "io.reads";
/// Counter: spill files that requested `O_DIRECT` but fell back to
/// buffered IO because the filesystem refused it (tmpfs does).
pub const C_IO_DIRECT_FALLBACK: &str = "io.direct.fallback";
/// Counter: v2 blocks a sharded-merge range open skipped entirely —
/// blocks in the run's directory that lie outside the shard's cut range
/// and are never read or decoded.
pub const C_BLOCKS_SKIPPED: &str = "shard.blocks.skipped";
/// Counter: run indexes served by an intact per-block min/max side-car
/// (no payload walk needed to build the block directory).
pub const C_SIDECAR_HIT: &str = "shard.sidecar.hit";
/// Counter: v2 run indexes that fell back to walking block headers
/// because the side-car was absent, stale, or corrupt.
pub const C_SIDECAR_MISS: &str = "shard.sidecar.miss";
/// Gauge: submission-queue depth of the IO pool (ops submitted but not
/// yet picked up by a worker), sampled at every submit/dequeue.
pub const G_IO_QUEUE: &str = "io.queue.depth";

/// Histograms every learned-path `extsort` telemetry document carries
/// (the acceptance set: spill volume, drift error, shard skew).
pub const BASE_EXTSORT_HISTS: &[&str] = &[
    M_SPILL_BYTES_ENCODED,
    M_SPILL_BYTES_RAW,
    M_DRIFT_ERROR,
    M_SHARD_SKEW,
];

/// Master switch for spans and the global metric helpers.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn tracing + global metrics collection on or off (off at startup).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// True while the observability layer is collecting.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Clear all recorded spans and global metrics (the per-job epoch:
/// `reset` → `set_enabled(true)` → run → [`job_telemetry`]).
pub fn reset() {
    trace::reset();
    metrics::reset();
}

/// Assemble the `JobTelemetry` document from the current trace buffer and
/// global metric registry. `report` is the job-level summary (e.g. an
/// `ExternalSortReport` as JSON); `None` serializes as `null`.
pub fn job_telemetry(report: Option<Json>) -> Json {
    let spans = trace::snapshot();
    telemetry_document(&trace::trace_tree(&spans), &metrics::snapshot(), report)
}

/// [`job_telemetry`] from explicit parts — the golden test builds a
/// deterministic document through this.
pub fn telemetry_document(
    tree: &[trace::TraceNode],
    metrics: &metrics::MetricsSnapshot,
    report: Option<Json>,
) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("schema".to_string(), Json::Str(SCHEMA.to_string()));
    let mut t = std::collections::BTreeMap::new();
    t.insert(
        "spans".to_string(),
        Json::Arr(tree.iter().map(trace::TraceNode::to_json).collect()),
    );
    m.insert("trace".to_string(), Json::Obj(t));
    m.insert("metrics".to_string(), metrics.to_json());
    m.insert("report".to_string(), report.unwrap_or(Json::Null));
    Json::Obj(m)
}

/// Collect every span name appearing in a telemetry document's trace
/// tree.
fn collect_names<'a>(node: &'a Json, out: &mut Vec<&'a str>) {
    if let Some(name) = node.get("name").and_then(Json::as_str) {
        out.push(name);
    }
    if let Some(Json::Arr(children)) = node.get("children") {
        for c in children {
            collect_names(c, out);
        }
    }
}

/// Validate a `JobTelemetry` document against the pinned schema:
/// the schema tag must match [`SCHEMA`], every span name must be in
/// [`KNOWN_SPANS`], every name in `required_spans` must appear, and every
/// histogram in `required_hists` must be present, well-formed, and
/// non-empty. Returns the first violation as an error message.
pub fn validate_telemetry(
    doc: &Json,
    required_spans: &[&str],
    required_hists: &[&str],
) -> Result<(), String> {
    match doc.get("schema").and_then(Json::as_str) {
        Some(s) if s == SCHEMA => {}
        Some(s) => return Err(format!("schema is {s:?}, expected {SCHEMA:?}")),
        None => return Err("missing schema field".to_string()),
    }
    let spans = doc
        .get("trace")
        .and_then(|t| t.get("spans"))
        .ok_or("missing trace.spans")?;
    let Json::Arr(roots) = spans else {
        return Err("trace.spans is not an array".to_string());
    };
    let mut names = Vec::new();
    for r in roots {
        collect_names(r, &mut names);
    }
    for n in &names {
        if !KNOWN_SPANS.contains(n) {
            return Err(format!("unknown span name {n:?}"));
        }
    }
    for want in required_spans {
        if !names.contains(want) {
            return Err(format!("required span {want:?} missing"));
        }
    }
    let metrics = doc.get("metrics").ok_or("missing metrics section")?;
    for section in ["counters", "gauges", "histograms"] {
        if !matches!(metrics.get(section), Some(Json::Obj(_))) {
            return Err(format!("metrics.{section} missing or not an object"));
        }
    }
    let hists = metrics.get("histograms").unwrap();
    for want in required_hists {
        let h = hists
            .get(want)
            .ok_or_else(|| format!("required histogram {want:?} missing"))?;
        let count = h
            .get("count")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("histogram {want:?} has no count"))?;
        if count < 1.0 {
            return Err(format!("histogram {want:?} is empty"));
        }
        if !matches!(h.get("buckets"), Some(Json::Arr(_))) {
            return Err(format!("histogram {want:?} has no buckets array"));
        }
    }
    if doc.get("report").is_none() {
        return Err("missing report field".to_string());
    }
    Ok(())
}

/// Serializes tests that flip the global enabled flag (spans and global
/// metrics are process-wide, so concurrent tests would cross-pollute).
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_doc() -> Json {
        let _l = test_lock();
        set_enabled(true);
        reset();
        {
            let mut job = trace::span(S_EXTSORT);
            job.set_keys(100);
            {
                let mut c = trace::span(S_CHUNK_SORT);
                c.set_keys(50);
            }
        }
        metrics::observe(M_DRIFT_ERROR, metrics::RATIO_BUCKETS, 0.02);
        set_enabled(false);
        job_telemetry(None)
    }

    #[test]
    fn telemetry_document_validates() {
        let doc = sample_doc();
        validate_telemetry(&doc, &[S_EXTSORT, S_CHUNK_SORT], &[M_DRIFT_ERROR])
            .expect("well-formed document validates");
    }

    #[test]
    fn missing_required_span_fails() {
        let doc = sample_doc();
        let err = validate_telemetry(&doc, &[S_RETRAIN], &[]).unwrap_err();
        assert!(err.contains("retrain"), "{err}");
    }

    #[test]
    fn missing_required_histogram_fails() {
        let doc = sample_doc();
        let err = validate_telemetry(&doc, &[], &[M_SHARD_SKEW]).unwrap_err();
        assert!(err.contains(M_SHARD_SKEW), "{err}");
    }

    #[test]
    fn unknown_span_name_fails() {
        let tree = vec![trace::TraceNode {
            name: "not-a-phase",
            count: 1,
            total_ns: 1,
            keys: 0,
            bytes: 0,
            children: Vec::new(),
        }];
        let doc =
            telemetry_document(&tree, &metrics::MetricsSnapshot::default(), None);
        let err = validate_telemetry(&doc, &[], &[]).unwrap_err();
        assert!(err.contains("not-a-phase"), "{err}");
    }

    #[test]
    fn wrong_schema_tag_fails() {
        let doc = Json::parse(r#"{"schema": "something.else.v9"}"#).unwrap();
        assert!(validate_telemetry(&doc, &[], &[]).is_err());
    }

    #[test]
    fn disabled_mode_records_no_spans_and_no_metrics() {
        let _l = test_lock();
        set_enabled(false);
        reset();
        {
            let mut s = trace::span(S_CHUNK_READ);
            s.set_keys(1);
        }
        metrics::counter_add(C_SPILL_RUNS, 1);
        metrics::observe(M_SHARD_SKEW, metrics::SKEW_BUCKETS, 2.0);
        assert_eq!(trace::span_count(), 0, "disabled: zero spans recorded");
        assert!(metrics::snapshot().is_empty(), "disabled: zero metrics");
    }

    #[test]
    fn roundtrips_through_the_json_parser() {
        let doc = sample_doc();
        let text = doc.dump();
        let back = Json::parse(&text).expect("serialized telemetry reparses");
        assert_eq!(back.get("schema").and_then(Json::as_str), Some(SCHEMA));
        validate_telemetry(&back, &[S_EXTSORT], &[M_DRIFT_ERROR]).unwrap();
    }
}
